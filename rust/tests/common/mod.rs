//! Helpers shared by the integration-test suites: queueing invariants
//! checked from raw per-request lifecycle events (`serve_sim.rs`,
//! `decode_sim.rs`, `fleet_sim.rs`), the golden-snapshot comparison
//! harness (`golden.rs`), and the seeded-artifact determinism check
//! every sweep suite runs. Everything works from public surfaces only,
//! so the same suite runs against any `BatchPolicy`-like scheduler —
//! FIFO co-batching, lock-step decode, continuous batching, and the
//! multi-replica fleet alike.

// Each integration-test crate compiles its own copy; not every crate
// uses every helper.
#![allow(dead_code)]

use std::fs;
use std::path::PathBuf;

use bertprof::serve::SimReport;
use bertprof::util::Json;

/// Time-average of N(t) over [0, makespan], integrated from raw
/// `(arrival, done)` spans — independent of any simulator's own
/// `mean_in_system` bookkeeping.
pub fn occupancy_by_event_integration(spans: &[(f64, f64)], makespan: f64) -> f64 {
    let mut events: Vec<(f64, f64)> = spans
        .iter()
        .flat_map(|&(arrival, done)| [(arrival, 1.0), (done, -1.0)])
        .collect();
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite times"));
    let (mut area, mut level, mut last) = (0.0_f64, 0.0_f64, 0.0_f64);
    for (t, delta) in events {
        area += level * (t - last);
        last = t;
        level += delta;
    }
    assert!(level.abs() < 1e-9, "system did not drain: {level}");
    area / makespan
}

/// Assert Little's law `L = λ·W` on a report, with the `L` side
/// re-integrated from the raw spans, and the report's own
/// `mean_in_system` agreeing with the integration.
pub fn assert_littles_law(report: &SimReport, spans: &[(f64, f64)]) {
    let l = occupancy_by_event_integration(spans, report.makespan);
    let lam_w = report.arrival_rate * report.mean_latency;
    assert!(
        (l - lam_w).abs() < 1e-6 * l.max(1e-12),
        "[{}] L {l} != λW {lam_w}",
        report.label
    );
    assert!(
        (report.mean_in_system - l).abs() < 1e-6 * l.max(1e-12),
        "[{}] report L {} != integrated L {l}",
        report.label,
        report.mean_in_system
    );
}

/// Every sweep artifact is a pure function of its seed: recomputing at
/// a different worker count must not move a byte, and reseeding must.
/// `artifact` maps `(seed, threads)` to the serialized artifact.
pub fn assert_seeded_artifact_determinism(
    artifact: impl Fn(u64, usize) -> String,
    base_seed: u64,
    other_seed: u64,
) {
    let a = artifact(base_seed, 4);
    let b = artifact(base_seed, 1);
    assert_eq!(a, b, "artifact must not depend on thread count");
    let c = artifact(other_seed, 4);
    assert_ne!(a, c, "different seed must change the trace");
}

// ------------------------------------------------------------------
// Golden-snapshot harness (used by golden.rs; hoisted here so other
// suites can pin artifacts against the same snapshots).
// ------------------------------------------------------------------

/// Relative tolerance for numeric fields: wide enough to absorb
/// benign float-accumulation differences, narrow enough that any real
/// model change (which shifts latencies by percents) trips it.
pub const REL_TOL: f64 = 1e-3;
/// Absolute floor for values near zero.
pub const ABS_TOL: f64 = 1e-9;

pub fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

pub fn update_mode() -> bool {
    std::env::var("UPDATE_GOLDEN").map(|v| v == "1").unwrap_or(false)
}

/// Object key holding wall-clock measurements (the gridscale
/// artifact's per-stage timings): volatile by construction, so the
/// comparison skips it entirely — in recursion and in both
/// missing-key directions (the mirror-written snapshot omits it; the
/// Rust artifact carries it).
pub const VOLATILE_KEY: &str = "timing";

/// Recursive field-by-field comparison; appends every divergence to
/// `errs` as a `path: detail` line.
pub fn diff(path: &str, want: &Json, got: &Json, errs: &mut Vec<String>) {
    match (want, got) {
        (Json::Num(a), Json::Num(b)) => {
            let tol = ABS_TOL + REL_TOL * a.abs().max(b.abs());
            if (a - b).abs() > tol {
                errs.push(format!("{path}: {a} != {b} (tol {tol:e})"));
            }
        }
        (Json::Str(a), Json::Str(b)) => {
            if a != b {
                errs.push(format!("{path}: {a:?} != {b:?}"));
            }
        }
        (Json::Bool(a), Json::Bool(b)) => {
            if a != b {
                errs.push(format!("{path}: {a} != {b}"));
            }
        }
        (Json::Null, Json::Null) => {}
        (Json::Arr(a), Json::Arr(b)) => {
            if a.len() != b.len() {
                errs.push(format!("{path}: array length {} != {}", a.len(), b.len()));
                return;
            }
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                diff(&format!("{path}[{i}]"), x, y, errs);
            }
        }
        (Json::Obj(a), Json::Obj(b)) => {
            for k in a.keys() {
                if k != VOLATILE_KEY && !b.contains_key(k) {
                    errs.push(format!("{path}.{k}: missing from computed artifact"));
                }
            }
            for k in b.keys() {
                if k != VOLATILE_KEY && !a.contains_key(k) {
                    errs.push(format!("{path}.{k}: not in golden snapshot"));
                }
            }
            for (k, x) in a {
                if k == VOLATILE_KEY {
                    continue;
                }
                if let Some(y) = b.get(k) {
                    diff(&format!("{path}.{k}"), x, y, errs);
                }
            }
        }
        _ => errs.push(format!("{path}: type mismatch ({want:?} vs {got:?})")),
    }
}

/// Compare `got` against the checked-in snapshot `<name>.json`, or
/// rewrite the snapshot when `UPDATE_GOLDEN=1`.
pub fn check(name: &str, got: Json) {
    let file = golden_dir().join(format!("{name}.json"));
    if update_mode() {
        fs::create_dir_all(golden_dir()).expect("golden dir");
        fs::write(&file, got.to_string()).expect("write snapshot");
        eprintln!("golden: regenerated {}", file.display());
        return;
    }
    let text = fs::read_to_string(&file).unwrap_or_else(|e| {
        panic!(
            "missing/unreadable golden snapshot {}: {e}\n\
             regenerate with: UPDATE_GOLDEN=1 cargo test --test golden",
            file.display()
        )
    });
    let want = Json::parse(&text).expect("golden snapshot parses");
    let mut errs = Vec::new();
    diff(name, &want, &got, &mut errs);
    assert!(
        errs.is_empty(),
        "golden mismatch for {name} — {} field(s) diverged:\n{}\n\
         if the model change is intentional, regenerate with \
         UPDATE_GOLDEN=1 cargo test --test golden and review the diff",
        errs.len(),
        errs.join("\n")
    );
}
