//! Property tests for the fleet-scale serving subsystem (DESIGN.md
//! SSFleet): request conservation across admission/rejection ledgers,
//! Little's law fleet-wide (re-integrated from raw completion spans),
//! round-robin fairness, the power-of-two-choices routing contract
//! audited from the per-request route records, autoscaler hysteresis,
//! the diurnal process's empirical mean rate, seed/thread determinism
//! of the sweep artifact, and the degenerate one-replica fleet
//! reproducing the single-replica simulator bit-for-bit.

use bertprof::config::{ModelConfig, Precision};
use bertprof::perf::device::DeviceSpec;
use bertprof::serve::sweep::report_json;
use bertprof::serve::{
    fleet_sweep_json, run_fleet_sweep, ArrivalProcess, AutoscalerConfig, BatchPolicy, Fleet,
    FleetOutcome, FleetSweepConfig, LatencyModel, Routing, Simulator, Workload, ROUTE_SEED_SALT,
};

mod common;

fn lm(dev: DeviceSpec) -> LatencyModel {
    LatencyModel::new(ModelConfig::bert_large(), Precision::Mixed, dev)
}

/// A homogeneous MI100 pool (equal service estimates, so routing
/// contracts reduce to pure depth comparisons).
fn mi100_pool(n: usize) -> Vec<(String, LatencyModel)> {
    (0..n).map(|_| ("MI100".to_string(), lm(DeviceSpec::mi100()))).collect()
}

/// The heterogeneous pool of the default sweep, small.
fn hetero_pool() -> Vec<(String, LatencyModel)> {
    vec![
        ("MI100".to_string(), lm(DeviceSpec::mi100())),
        ("A100".to_string(), lm(DeviceSpec::a100())),
        ("V100".to_string(), lm(DeviceSpec::v100())),
    ]
}

fn run_fleet(
    fleet: Fleet,
    trace_rate: f64,
    requests: u64,
    seed: u64,
    pool: Vec<(String, LatencyModel)>,
    routing: Routing,
) -> FleetOutcome {
    let trace = ArrivalProcess::Fixed { rate: trace_rate }.generate(requests, seed, 16, 128);
    let mut policy = routing.build();
    fleet.run("prop", &trace, pool, policy.as_mut(), seed ^ ROUTE_SEED_SALT)
}

#[test]
fn prop_requests_are_conserved_across_every_ledger() {
    // Offered = admitted + rejected, per replica and fleet-wide; every
    // admitted request completes after the final drain; the route
    // records' own admission flags agree with the replica counters.
    for (cap, seed) in [(None, 3u64), (Some(2), 4), (Some(6), 5)] {
        let mut fleet = Fleet::new(BatchPolicy::new(8, 0.010), 0.1);
        if let Some(c) = cap {
            fleet = fleet.with_queue_cap(c);
        }
        let out = run_fleet(fleet, 3_000.0, 1_500, seed, mi100_pool(3), Routing::LeastLoaded);
        let r = &out.report;
        assert_eq!(r.arrivals, 1_500);
        assert_eq!(r.admitted + r.rejected, r.arrivals, "cap {cap:?}");
        assert_eq!(out.completions.len() as u64, r.admitted);
        let per_admitted: u64 = r.replicas.iter().map(|s| s.assigned).sum();
        let per_completed: u64 = r.replicas.iter().map(|s| s.completed).sum();
        let per_rejected: u64 = r.replicas.iter().map(|s| s.rejected).sum();
        assert_eq!(per_admitted, r.admitted);
        assert_eq!(per_completed, r.admitted, "a queued request vanished");
        assert_eq!(per_rejected, r.rejected);
        for (i, ledger) in out.per_replica.iter().enumerate() {
            assert_eq!(ledger.len() as u64, r.replicas[i].completed);
        }
        let route_admitted = out.routes.iter().filter(|x| x.admitted).count() as u64;
        assert_eq!(route_admitted, r.admitted);
        if cap.is_none() {
            assert_eq!(r.rejected, 0);
        } else {
            assert!(r.rejected > 0, "overload at cap {cap:?} must reject");
        }
    }
}

#[test]
fn prop_littles_law_holds_fleet_wide() {
    // The same `L = λ·W` identity the single-replica suites assert,
    // here over the merged multi-replica ledger under a heterogeneous
    // pool and a diurnal arrival process.
    let arrivals = ArrivalProcess::Diurnal { base: 250.0, amplitude: 0.6, period: 3.0 };
    let trace = arrivals.generate(2_000, 11, 16, 128);
    let mut policy = Routing::PowerOfTwo.build();
    let out = Fleet::new(BatchPolicy::new(8, 0.010), 0.1).run(
        "little",
        &trace,
        hetero_pool(),
        policy.as_mut(),
        11 ^ ROUTE_SEED_SALT,
    );
    let spans: Vec<(f64, f64)> =
        out.completions.iter().map(|c| (c.arrival, c.done)).collect();
    common::assert_littles_law(&out.report.sim, &spans);
}

#[test]
fn prop_round_robin_is_fair_on_a_homogeneous_pool() {
    // Equal service rates, no autoscaler, no cap: round-robin assigns
    // within one request of perfectly even.
    let out = run_fleet(
        Fleet::new(BatchPolicy::new(8, 0.010), 0.1),
        600.0,
        1_001, // deliberately not divisible by the pool size
        21,
        mi100_pool(4),
        Routing::RoundRobin,
    );
    let assigned: Vec<u64> = out.report.replicas.iter().map(|s| s.assigned).collect();
    let (min, max) = (
        *assigned.iter().min().expect("non-empty pool"),
        *assigned.iter().max().expect("non-empty pool"),
    );
    assert!(max - min <= 1, "round-robin drifted: {assigned:?}");
    assert_eq!(assigned.iter().sum::<u64>(), 1_001);
}

#[test]
fn prop_p2c_routes_to_the_better_sampled_candidate() {
    // Audit every routing decision from the records: the chosen replica
    // is one of the two sampled candidates, and (equal service
    // estimates) never the strictly deeper one.
    let out = run_fleet(
        Fleet::new(BatchPolicy::new(8, 0.010), 0.1),
        700.0,
        2_000,
        31,
        mi100_pool(4),
        Routing::PowerOfTwo,
    );
    let mut sampled_decisions = 0;
    for rec in &out.routes {
        let Some((a, b)) = rec.sampled else { continue };
        sampled_decisions += 1;
        assert_ne!(a, b, "p2c sampled the same replica twice");
        assert!(
            rec.chosen == a || rec.chosen == b,
            "chose {} outside the sample ({a},{b})",
            rec.chosen
        );
        let other = if rec.chosen == a { b } else { a };
        assert!(
            rec.depths[rec.chosen] <= rec.depths[other],
            "req {}: chose depth {} over {}",
            rec.id,
            rec.depths[rec.chosen],
            rec.depths[other]
        );
    }
    assert_eq!(sampled_decisions, out.routes.len(), "pool > 1 always samples");
}

#[test]
fn prop_autoscaler_hysteresis_spaces_decisions() {
    // Consecutive scale events are always more than one cooldown window
    // apart, and the active count stays within [min, max].
    let auto = AutoscalerConfig {
        enabled: true,
        min_replicas: 1,
        max_replicas: 4,
        up_threshold: 10.0,
        down_threshold: 2.0,
        tick: 0.05,
        cooldown_ticks: 3,
        warmup: 0.05,
    };
    let arrivals = ArrivalProcess::Diurnal { base: 200.0, amplitude: 0.8, period: 2.5 };
    let trace = arrivals.generate(3_000, 41, 16, 128);
    let mut policy = Routing::LeastLoaded.build();
    let out = Fleet::new(BatchPolicy::new(8, 0.010), 0.1)
        .with_autoscaler(auto)
        .run("hyst", &trace, mi100_pool(4), policy.as_mut(), 41 ^ ROUTE_SEED_SALT);
    assert_eq!(out.completions.len(), 3_000);
    assert!(
        out.report.scale_ups >= 1,
        "the diurnal peak never tripped a scale-up"
    );
    let min_gap = (auto.cooldown_ticks + 1) as f64 * auto.tick;
    for w in out.scale_events.windows(2) {
        assert!(
            w[1].time - w[0].time >= min_gap - 1e-9,
            "events {:.3}s apart inside the {min_gap:.3}s cooldown window",
            w[1].time - w[0].time
        );
        assert!((1..=4).contains(&w[1].active_after));
    }
}

#[test]
fn prop_diurnal_empirical_rate_matches_the_analytic_mean() {
    // Over many whole periods the thinned sinusoid's empirical rate
    // (n / span) converges to `base`; the flash crowd's stays between
    // base and burst.
    let base = 100.0;
    let p = ArrivalProcess::Diurnal { base, amplitude: 0.6, period: 10.0 };
    assert_eq!(p.mean_rate(), base);
    let trace = p.generate(20_000, 77, 16, 128);
    let span = trace.last().expect("non-empty").arrival;
    let empirical = trace.len() as f64 / span;
    assert!(
        (empirical - base).abs() < 0.05 * base,
        "empirical {empirical:.1}/s vs analytic {base:.1}/s"
    );
    let f = ArrivalProcess::FlashCrowd {
        base,
        burst_rate: 250.0,
        burst_start: 50.0,
        burst_len: 20.0,
    };
    let ftrace = f.generate(20_000, 77, 16, 128);
    let frate = ftrace.len() as f64 / ftrace.last().expect("non-empty").arrival;
    assert!(frate > base && frate < 250.0, "flash rate {frate:.1}/s out of band");
}

#[test]
fn prop_same_seed_same_artifact() {
    // The sweep artifact is a pure function of the seed: byte-identical
    // across worker counts, different under a reseed (the shared
    // helper every sweep suite runs).
    common::assert_seeded_artifact_determinism(
        |seed, threads| {
            let mut cfg = FleetSweepConfig::bert_large_default();
            cfg.requests = 600;
            cfg.seed = seed;
            fleet_sweep_json(&cfg, &run_fleet_sweep(&cfg, threads)).to_string()
        },
        42,
        7,
    );
}

#[test]
fn degenerate_fleet_reproduces_the_single_replica_simulator() {
    // A 1-replica homogeneous fleet with round-robin routing and the
    // autoscaler off IS the single-replica simulator: same trace, same
    // report (bit-for-bit through the shared constructor), same
    // completion ledger. This identity is what lets the fleet numbers
    // extend every earlier serving study without a new baseline.
    for (max_batch, seed) in [(1u64, 51u64), (8, 52), (32, 53)] {
        let trace = Workload::poisson(180.0, 1_000, seed)
            .with_seq_range(16, 128)
            .generate();
        let policy = BatchPolicy::new(max_batch, 0.010);
        let solo = Simulator::new(policy, 0.1).run("twin", &trace, &mut lm(DeviceSpec::mi100()));
        let mut rr = Routing::RoundRobin.build();
        let fleet = Fleet::new(policy, 0.1).run(
            "twin",
            &trace,
            mi100_pool(1),
            rr.as_mut(),
            seed ^ ROUTE_SEED_SALT,
        );
        assert_eq!(
            report_json(&fleet.report.sim).to_string(),
            report_json(&solo.report).to_string(),
            "B{max_batch} report diverged"
        );
        assert_eq!(fleet.completions.len(), solo.completions.len());
        for (a, b) in fleet.completions.iter().zip(&solo.completions) {
            assert_eq!(a.id, b.id, "B{max_batch}");
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.done, b.done, "B{max_batch} req {}", a.id);
            assert_eq!(a.batch_size, b.batch_size);
            assert_eq!(a.padded_seq, b.padded_seq);
        }
        // And the fleet-only ledgers collapse to the trivial values.
        assert_eq!(fleet.report.replicas.len(), 1);
        assert_eq!(fleet.report.scale_ups + fleet.report.scale_downs, 0);
        assert!((fleet.report.util_spread).abs() < 1e-12);
    }
}
