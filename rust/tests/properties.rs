//! Property-based tests (randomized with the in-tree PRNG — proptest is
//! unavailable offline): invariants of the op-graph, roofline, GEMM,
//! distributed, and JSON substrates over hundreds of random
//! configurations.

use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::dist::allreduce::{ring_allreduce_time, ring_allreduce_volume};
use bertprof::dist::LinkSpec;
use bertprof::model::gemm::{table3, GemmDims, GemmKind};
use bertprof::model::IterationGraph;
use bertprof::perf::device::DeviceSpec;
use bertprof::perf::gemm_model::{gemm_efficiency, gemm_time};
use bertprof::perf::roofline::iteration_seconds;
use bertprof::util::{Json, Rng};

/// Random-but-valid model config.
fn random_config(rng: &mut Rng) -> ModelConfig {
    let heads = [4u64, 8, 16][rng.int_range(0, 2) as usize];
    let d_model = heads * 64 * rng.int_range(1, 3) as u64;
    ModelConfig {
        batch: rng.int_range(1, 64) as u64,
        seq_len: [32u64, 64, 128, 256, 512][rng.int_range(0, 4) as usize],
        d_model,
        n_heads: heads,
        d_ff: 4 * d_model,
        n_layers: rng.int_range(1, 48) as u64,
        vocab: rng.int_range(1000, 50000) as u64,
        max_seq_len: 512,
        type_vocab: 2,
    }
}

#[test]
fn prop_graph_flops_scale_linearly_with_layer_count() {
    let mut rng = Rng::seed(11);
    for _ in 0..50 {
        let cfg = random_config(&mut rng);
        let r1 = RunConfig::new(cfg.with_layers(8), Phase::Phase1, Precision::Fp32);
        let r2 = RunConfig::new(cfg.with_layers(16), Phase::Phase1, Precision::Fp32);
        let f = |r: &RunConfig| {
            IterationGraph::build(r)
                .ops_in_layer(bertprof::model::op::LayerClass::Transformer)
                .map(|o| o.total_flops())
                .sum::<u64>()
        };
        assert_eq!(2 * f(&r1), f(&r2), "{cfg:?}");
    }
}

#[test]
fn prop_precision_never_changes_flops_only_bytes() {
    let mut rng = Rng::seed(12);
    for _ in 0..50 {
        let cfg = random_config(&mut rng);
        let a = IterationGraph::build(&RunConfig::new(cfg, Phase::Phase1, Precision::Fp32));
        let b = IterationGraph::build(&RunConfig::new(cfg, Phase::Phase1, Precision::Mixed));
        assert_eq!(a.total_flops(), b.total_flops());
        assert!(a.total_bytes() > b.total_bytes());
    }
}

#[test]
fn prop_roofline_time_monotone_in_bandwidth_and_compute() {
    let mut rng = Rng::seed(13);
    for _ in 0..30 {
        let cfg = random_config(&mut rng);
        let run = RunConfig::new(cfg, Phase::Phase1, Precision::Fp32);
        let g = IterationGraph::build(&run);
        let base = DeviceSpec::mi100();
        let mut fast_mem = base.clone();
        fast_mem.mem_bw *= 2.0;
        let mut fast_compute = base.clone();
        fast_compute.fp32_matrix_flops *= 2.0;
        fast_compute.fp32_vector_flops *= 2.0;
        let t0 = iteration_seconds(&g, &base, run.precision);
        assert!(iteration_seconds(&g, &fast_mem, run.precision) <= t0 + 1e-12);
        assert!(iteration_seconds(&g, &fast_compute, run.precision) <= t0 + 1e-12);
    }
}

#[test]
fn prop_gemm_efficiency_in_unit_interval_and_monotone_in_size() {
    let mut rng = Rng::seed(14);
    for _ in 0..200 {
        let m = rng.int_range(1, 8192) as u64;
        let n = rng.int_range(1, 8192) as u64;
        let k = rng.int_range(1, 8192) as u64;
        let b = rng.int_range(1, 64) as u64;
        let g = GemmDims::new(GemmKind::Fc1, m, n, k, b);
        let e = gemm_efficiency(&g);
        assert!(e > 0.0 && e <= 1.0, "{g:?} -> {e}");
        // Doubling every dim never reduces efficiency.
        let g2 = GemmDims::new(GemmKind::Fc1, 2 * m, 2 * n, 2 * k, b);
        assert!(gemm_efficiency(&g2) >= e - 1e-9, "{g:?}");
    }
}

#[test]
fn prop_gemm_time_positive_and_superlinear_total() {
    let dev = DeviceSpec::mi100();
    let mut rng = Rng::seed(15);
    for _ in 0..100 {
        let m = rng.int_range(16, 4096) as u64;
        let n = rng.int_range(16, 4096) as u64;
        let k = rng.int_range(16, 4096) as u64;
        let g = GemmDims::new(GemmKind::Fc1, m, n, k, 1);
        let t = gemm_time(&g, &dev, Precision::Fp32);
        assert!(t > 0.0 && t.is_finite());
        // 8x the flops never runs faster.
        let g8 = GemmDims::new(GemmKind::Fc1, 2 * m, 2 * n, 2 * k, 1);
        assert!(gemm_time(&g8, &dev, Precision::Fp32) >= t);
    }
}

#[test]
fn prop_table3_dims_always_token_or_width_multiples() {
    // Takeaway 6 generalized: every GEMM dim is one of n, n*B, d, d/h,
    // or d_ff for ANY hyperparameters.
    let mut rng = Rng::seed(16);
    for _ in 0..50 {
        let cfg = random_config(&mut rng);
        let allowed = [cfg.seq_len, cfg.tokens(), cfg.d_model, cfg.d_head(), cfg.d_ff];
        for row in table3(&cfg) {
            for g in [row.fwd, row.bwd_dgrad, row.bwd_wgrad] {
                for dim in [g.m, g.n, g.k] {
                    assert!(allowed.contains(&dim), "{dim} not in {allowed:?}");
                }
            }
        }
    }
}

#[test]
fn prop_allreduce_volume_bounded_by_2x_payload() {
    let mut rng = Rng::seed(17);
    for _ in 0..200 {
        let bytes = rng.int_range(1, 1 << 33) as u64;
        let devices = rng.int_range(1, 512) as u64;
        let v = ring_allreduce_volume(bytes, devices);
        assert!(v <= 2 * bytes, "{v} > 2*{bytes}");
        let t = ring_allreduce_time(bytes, devices, &LinkSpec::pcie4x16());
        assert!(t >= 0.0 && t.is_finite());
        // More devices never shrinks the time (same payload).
        if devices >= 2 {
            let t2 = ring_allreduce_time(bytes, devices * 2, &LinkSpec::pcie4x16());
            assert!(t2 >= t - 1e-12);
        }
    }
}

#[test]
fn prop_lamb_bytes_invariant_under_batch_and_seq() {
    let mut rng = Rng::seed(18);
    for _ in 0..50 {
        let cfg = random_config(&mut rng);
        let mut cfg2 = cfg;
        cfg2.batch = cfg.batch * 2;
        cfg2.seq_len = cfg.seq_len / 2 + 1;
        let f = |c: ModelConfig| -> u64 {
            bertprof::model::lamb::lamb_ops(
                &RunConfig::new(c, Phase::Phase1, Precision::Fp32))
                .iter().map(|o| o.total_bytes()).sum()
        };
        assert_eq!(f(cfg), f(cfg2));
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::seed(19);
    fn random_json(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.int_range(0, 3) } else { rng.int_range(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.int_range(-1_000_000, 1_000_000) as f64) / 8.0),
            3 => Json::Str(format!("s{}-\"quoted\"\n{}", rng.next_u64(),
                                    rng.int_range(0, 9))),
            4 => Json::Arr((0..rng.int_range(0, 4))
                .map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::obj(
                vec![("a", random_json(rng, depth - 1)),
                     ("b", random_json(rng, depth - 1))]),
        }
    }
    for _ in 0..300 {
        let j = random_json(&mut rng, 3);
        let txt = j.to_string();
        let back = Json::parse(&txt).unwrap_or_else(|e| panic!("{txt}: {e}"));
        assert_eq!(back, j, "{txt}");
    }
}

#[test]
fn prop_timeline_fractions_always_sum_to_one() {
    let mut rng = Rng::seed(20);
    for _ in 0..30 {
        let cfg = random_config(&mut rng);
        for prec in [Precision::Fp32, Precision::Mixed] {
            let run = RunConfig::new(cfg, Phase::Phase1, prec);
            let t = bertprof::profiler::Timeline::modeled(&run, &DeviceSpec::mi100());
            let s: f64 = t.layer_fractions().values().sum();
            assert!((s - 1.0).abs() < 1e-9, "{cfg:?}");
        }
    }
}
