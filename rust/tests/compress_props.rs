//! Property tests for the compression subsystem (DESIGN.md SSCompress)
//! plus the cross-subsystem serve/train consistency check:
//!
//! * structured pruning is monotone — it never increases the FLOPs or
//!   bytes of any op, over hundreds of random configurations and specs;
//! * the INT8 ladder orders GEMM times INT8 <= Mixed <= FP32 on devices
//!   whose integer engine matches (or beats) their fp16 rate;
//! * a pruned-then-rebuilt graph still satisfies the core graph
//!   invariants `rust/tests/properties.rs` pins for dense graphs;
//! * `serve`'s inference graph at a compressed config equals the
//!   training graph's forward slice after the same prune transform,
//!   op-for-op.

use bertprof::compress::{CompressPrecision, PruneSpec};
use bertprof::compress::{quant, CompressVariant, CompressedLatencyModel};
use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::model::gemm::table3;
use bertprof::model::op::LayerClass;
use bertprof::model::IterationGraph;
use bertprof::perf::device::DeviceSpec;
use bertprof::perf::gemm_model::{gemm_time, is_memory_bound};
use bertprof::serve::{forward_graph, inference_run, BatchCost, ServeHead};
use bertprof::util::Rng;

/// Random-but-valid model config (the `properties.rs` generator).
fn random_config(rng: &mut Rng) -> ModelConfig {
    let heads = [4u64, 8, 16][rng.int_range(0, 2) as usize];
    let d_model = heads * 64 * rng.int_range(1, 3) as u64;
    ModelConfig {
        batch: rng.int_range(1, 64) as u64,
        seq_len: [32u64, 64, 128, 256, 512][rng.int_range(0, 4) as usize],
        d_model,
        n_heads: heads,
        d_ff: 4 * d_model,
        n_layers: rng.int_range(1, 48) as u64,
        vocab: rng.int_range(1000, 50000) as u64,
        max_seq_len: 512,
        type_vocab: 2,
    }
}

/// Random non-trivial prune spec for `cfg`.
fn random_spec(rng: &mut Rng, cfg: &ModelConfig) -> PruneSpec {
    PruneSpec::dense(cfg)
        .keep_heads(rng.int_range(1, cfg.n_heads as i64) as u64)
        .keep_ff(rng.int_range(1, cfg.d_ff as i64) as u64)
        .keep_layers(rng.int_range(1, cfg.n_layers as i64) as u64)
}

#[test]
fn prop_pruning_is_monotone_per_op() {
    use bertprof::model::op::OpCategory;
    use std::collections::HashMap;
    let per_category = |g: &IterationGraph| -> HashMap<OpCategory, (u64, u64)> {
        let mut m = HashMap::new();
        for o in &g.ops {
            let e = m.entry(o.category).or_insert((0u64, 0u64));
            e.0 += o.total_flops();
            e.1 += o.total_bytes();
        }
        m
    };
    let mut rng = Rng::seed(31);
    for _ in 0..60 {
        let cfg = random_config(&mut rng);
        // RunConfig::new pins seq_len to the phase; the transform must
        // see the graph's own (post-phase) config so Table 3 shapes match.
        let run = RunConfig::new(cfg, Phase::Phase1, Precision::Fp32);
        let g = IterationGraph::build(&run);
        let spec = random_spec(&mut rng, &cfg);
        let pruned = spec.apply(&run.model, &g);
        // Head pruning splits the aggregated projection op into Q/K/V +
        // Wo, so compare per-category (and, when no split happened,
        // per-op) — pruning must never increase work anywhere.
        let dense_cat = per_category(&g);
        for (cat, (fl, by)) in per_category(&pruned) {
            let (dfl, dby) = dense_cat[&cat];
            assert!(fl <= dfl, "{spec:?} raised {cat:?} flops: {dfl} -> {fl}");
            assert!(by <= dby, "{spec:?} raised {cat:?} bytes: {dby} -> {by}");
        }
        if g.ops.len() == pruned.ops.len() {
            for (dense, small) in g.ops.iter().zip(&pruned.ops) {
                assert!(
                    small.total_flops() <= dense.total_flops()
                        && small.total_bytes() <= dense.total_bytes(),
                    "{spec:?} raised {}",
                    dense.name
                );
            }
        }
        assert!(pruned.total_flops() <= g.total_flops());
        assert!(pruned.total_bytes() <= g.total_bytes());
        assert!(spec.param_count(&cfg) <= cfg.param_count());
    }
}

#[test]
fn prop_int8_ladder_orders_gemm_times() {
    // INT8 <= Mixed <= FP32 for every Table 3 GEMM on devices whose
    // integer engine matches/beats fp16 — strict where FP32 is
    // compute-bound (the rate advantage must show).
    let mut rng = Rng::seed(32);
    for dev in [DeviceSpec::mi100(), DeviceSpec::a100()] {
        for _ in 0..25 {
            let cfg = random_config(&mut rng);
            for row in table3(&cfg) {
                for g in [row.fwd, row.bwd_dgrad, row.bwd_wgrad] {
                    let t32 = gemm_time(&g, &dev, Precision::Fp32);
                    let t16 = gemm_time(&g, &dev, Precision::Mixed);
                    let t8 = gemm_time(&g, &dev, Precision::Int8);
                    assert!(t16 <= t32 + 1e-15, "{:?} {} {t16} !<= {t32}", g, dev.name);
                    assert!(t8 <= t16 + 1e-15, "{:?} {} {t8} !<= {t16}", g, dev.name);
                    if !is_memory_bound(&g, &dev, Precision::Fp32) {
                        assert!(t8 < t32, "{:?} {} {t8} !< {t32}", g, dev.name);
                    }
                }
            }
        }
    }
}

#[test]
fn prop_pruned_then_rebuilt_graph_keeps_dense_invariants() {
    // The invariants `properties.rs` pins for dense graphs, re-asserted
    // on pruned graphs: transformer flops linear in kept layers,
    // precision changes bytes but never flops, and the graph stays
    // non-degenerate.
    let mut rng = Rng::seed(33);
    for _ in 0..40 {
        let cfg = random_config(&mut rng);
        let spec = random_spec(&mut rng, &cfg);

        // Linear in kept layers (hold the other axes fixed).
        if cfg.n_layers >= 2 {
            let l = (spec.n_layers / 2).max(1);
            let run = RunConfig::new(cfg, Phase::Phase1, Precision::Fp32);
            let g = IterationGraph::build(&run);
            let tf = |s: PruneSpec| -> u64 {
                s.apply(&run.model, &g)
                    .ops_in_layer(LayerClass::Transformer)
                    .map(|o| o.total_flops())
                    .sum()
            };
            assert_eq!(2 * tf(spec.keep_layers(l)), tf(spec.keep_layers(2 * l)), "{cfg:?}");
        }

        // Precision never changes flops, only bytes.
        let a = {
            let run = RunConfig::new(cfg, Phase::Phase1, Precision::Fp32);
            spec.apply(&run.model, &IterationGraph::build(&run))
        };
        let b = {
            let run = RunConfig::new(cfg, Phase::Phase1, Precision::Mixed);
            spec.apply(&run.model, &IterationGraph::build(&run))
        };
        assert_eq!(a.total_flops(), b.total_flops());
        assert!(a.total_bytes() > b.total_bytes());

        // Non-degenerate: every op class present, GEMMs still majority.
        assert!(a.ops.len() > 20);
        assert!(a.gemm_flop_fraction() > 0.5, "{}", a.gemm_flop_fraction());
    }
}

#[test]
fn prop_expressible_specs_equal_rebuilt_configs() {
    // Randomized form of the strongest consistency check: for specs
    // expressible as a ModelConfig (full heads), the graph transform is
    // *identical* to building the smaller model.
    let mut rng = Rng::seed(34);
    for _ in 0..40 {
        let cfg = random_config(&mut rng);
        let spec = PruneSpec::dense(&cfg)
            .keep_ff(rng.int_range(1, cfg.d_ff as i64) as u64)
            .keep_layers(rng.int_range(1, cfg.n_layers as i64) as u64);
        let run = RunConfig::new(cfg, Phase::Phase1, Precision::Fp32);
        let pruned = spec.apply(&run.model, &IterationGraph::build(&run));
        let mut small = run.model.with_layers(spec.n_layers);
        small.d_ff = spec.d_ff;
        let rebuilt = IterationGraph::build(&RunConfig::new(small, Phase::Phase1,
                                                           Precision::Fp32));
        assert_eq!(pruned.ops, rebuilt.ops, "{cfg:?} {spec:?}");
    }
}

#[test]
fn cross_subsystem_serve_graph_equals_pruned_training_forward_slice() {
    // The serving path (inference_run -> forward_graph -> prune) and the
    // training path (build -> prune -> forward slice) must agree
    // op-for-op at every compressed config — the compressed what-ifs
    // answer serving questions with training-consistent graphs.
    let model = ModelConfig::bert_large();
    let specs = [
        PruneSpec::dense(&model),
        PruneSpec::dense(&model).keep_heads(8),
        PruneSpec::dense(&model).keep_heads(12).keep_ff(2048).keep_layers(12),
    ];
    for spec in specs {
        for (batch, seq) in [(1u64, 64u64), (8, 96), (32, 384)] {
            for prec in [Precision::Fp32, Precision::Mixed, Precision::Int8] {
                let run = inference_run(model, batch, seq, prec);
                let serve_side = spec.apply(&run.model, &forward_graph(&run, ServeHead::Pretrain));
                let train_side = spec.apply(&run.model, &IterationGraph::build(&run))
                    .forward_slice();
                assert_eq!(
                    serve_side.ops, train_side.ops,
                    "{spec:?} B{batch} n{seq} {prec:?}"
                );
            }
        }
    }
}

#[test]
fn prop_compressed_latency_monotone_in_compression() {
    // More compression never serves slower: at any (batch, seq) shape,
    // the pruned+quantized ladder is ordered on MI100.
    let model = ModelConfig::bert_large();
    let dev = DeviceSpec::mi100();
    let dense = PruneSpec::dense(&model);
    let pruned = dense.keep_heads(8).keep_ff(2048);
    let mut rng = Rng::seed(35);
    for _ in 0..10 {
        let batch = rng.int_range(1, 64) as u64;
        let seq = rng.int_range(16, 512) as u64;
        let secs = |name: &str, spec: PruneSpec, cp: CompressPrecision| {
            let v = CompressVariant::new(name, spec, cp);
            CompressedLatencyModel::new(model, &v, dev.clone()).batch_seconds(batch, seq)
        };
        let d32 = secs("d32", dense, CompressPrecision::Fp32);
        let d16 = secs("d16", dense, CompressPrecision::Mixed);
        let p16 = secs("p16", pruned, CompressPrecision::Mixed);
        let p8 = secs("p8", pruned, CompressPrecision::Int8Full);
        assert!(d16 <= d32, "B{batch} n{seq}: {d16} !<= {d32}");
        assert!(p16 <= d16, "B{batch} n{seq}: {p16} !<= {d16}");
        assert!(p8 <= p16, "B{batch} n{seq}: {p8} !<= {p16}");
    }
}

#[test]
fn quantized_graphs_price_every_op() {
    // graph_seconds must be a strict sum over ops: removing any op
    // reduces it (guards against silently dropping categories).
    let run = inference_run(ModelConfig::bert_large(), 8, 128, Precision::Int8);
    let g = forward_graph(&run, ServeHead::Squad);
    let dev = DeviceSpec::mi100();
    let full = quant::graph_seconds(&g, &dev, CompressPrecision::Int8Full);
    let mut partial = g.clone();
    let _ = partial.ops.pop();
    let less = quant::graph_seconds(&partial, &dev, CompressPrecision::Int8Full);
    assert!(less < full);
    assert!(full.is_finite() && full > 0.0);
}
