//! Golden-artifact regression harness.
//!
//! Each test computes one of the crate's canonical JSON artifacts —
//! the Fig. 4 / Fig. 7 / Fig. 9 / Fig. 12 figure artifacts
//! (`profiler::artifact`), the serve sweep, and the compress sweep —
//! and compares it field-by-field against the checked-in snapshot under
//! `rust/tests/golden/`. Numbers compare with a relative tolerance
//! (modeling changes move numbers by far more; float noise moves them
//! by far less); strings, booleans, array lengths, and object key sets
//! compare exactly. Every artifact is a pure function of the crate +
//! its seed, so a mismatch means the model changed — regenerate with
//!
//! ```text
//! UPDATE_GOLDEN=1 cargo test --test golden
//! ```
//!
//! and review the snapshot diff like any other code change.

use bertprof::compress::{self, CompressPrecision, CompressSweepConfig, CompressVariant};
use bertprof::perf::device::DeviceSpec;
use bertprof::perf::CalibrationTable;
use bertprof::profiler::artifact;
use bertprof::serve::{self, DecodeSweepConfig, FleetSweepConfig, SweepConfig};
use bertprof::util::Json;

mod common;

// The comparison harness (REL_TOL diff + UPDATE_GOLDEN regeneration)
// lives in tests/common so every suite pins artifacts the same way.
use common::{check, golden_dir};

/// The reduced serve grid the snapshot pins: MI100, FP32 vs Mixed,
/// B1/B8, 1000 requests — small enough to run in seconds, rich enough
/// that graph, roofline, RNG, and simulator all feed the artifact.
fn serve_golden_cfg() -> SweepConfig {
    let mut cfg = SweepConfig::bert_large_default();
    cfg.requests = 1_000;
    cfg.max_batches = vec![1, 8];
    cfg
}

/// The reduced decode grid the snapshot pins: MI100, FP32 vs Mixed,
/// 8 vs 32 slots, 500 requests — both schedulers at every point, so the
/// continuous-vs-FIFO verdicts are golden-gated too.
fn decode_golden_cfg() -> DecodeSweepConfig {
    let mut cfg = DecodeSweepConfig::bert_large_default();
    cfg.requests = 500;
    cfg
}

/// The reduced fleet grid the snapshot pins: the default pools,
/// arrivals, routers, and autoscaler settings at 2000 requests — every
/// verdict and the cost frontier ride inside the snapshot.
fn fleet_golden_cfg() -> FleetSweepConfig {
    let mut cfg = FleetSweepConfig::bert_large_default();
    cfg.requests = 2_000;
    cfg
}

/// The reduced compress grid: MI100 only, the dense FP32/FP16 anchors
/// plus the headline pruned+INT8 variant, B32, 800 requests.
fn compress_golden_cfg() -> CompressSweepConfig {
    let mut cfg = CompressSweepConfig::bert_large_default();
    cfg.devices = vec![DeviceSpec::mi100()];
    cfg.requests = 800;
    cfg.max_batches = vec![32];
    cfg.variants = vec![
        CompressVariant::dense(&cfg.model, CompressPrecision::Fp32),
        CompressVariant::dense(&cfg.model, CompressPrecision::Mixed),
        compress::default_variants(&cfg.model).pop().expect("pruned-w8a8"),
    ];
    cfg
}

#[test]
fn golden_fig04_runtime_breakdown() {
    check("fig04", artifact::fig04_json(&DeviceSpec::mi100()));
}

#[test]
fn golden_fig07_gemm_intensity() {
    // The newly artifact-emitting scenario (ISSUE 4 satellite): the
    // registry's fig07 path is golden-gated end to end.
    check("fig07", artifact::fig07_json(&DeviceSpec::mi100()));
}

#[test]
fn golden_fig07_matches_the_scenario_registry_path() {
    // `bertprof run fig07` emits exactly the golden-gated artifact.
    let out = bertprof::scenario::run_by_name("fig07", &[], true).expect("fig07 runs");
    check("fig07", out.artifact);
}

#[test]
fn golden_fig09_batch_sweep() {
    check("fig09", artifact::fig09_json(&DeviceSpec::mi100()));
}

#[test]
fn golden_fig12_distributed() {
    check("fig12", artifact::fig12_json(&DeviceSpec::mi100()));
}

#[test]
fn golden_serve_sweep() {
    let cfg = serve_golden_cfg();
    let reports = serve::run_sweep(&cfg, 2);
    check("serve_sweep", serve::sweep_json(&cfg, &reports));
}

/// The checked-in example calibration table (the SSHardware-Adaptation
/// seam's documentation artifact).
fn example_cost_table() -> CalibrationTable {
    let path = golden_dir()
        .parent()
        .and_then(|p| p.parent())
        .and_then(|p| p.parent())
        .expect("repo root")
        .join("examples/cost_table_mi100.json");
    CalibrationTable::load(&path).expect("example calibration table loads")
}

#[test]
fn golden_serve_calibrated_sweep() {
    // The ISSUE 5 acceptance artifact: `bertprof run serve --set
    // requests=1000 --set max-batches=1,8 --set
    // cost_table=examples/cost_table_mi100.json` — a *non-identity*
    // calibration, mirror-validated (golden_mirror.py regenerates this
    // snapshot through its calibration hook).
    let mut cfg = serve_golden_cfg();
    cfg.calibration = Some(example_cost_table());
    let reports = serve::run_sweep(&cfg, 2);
    check("serve_calibrated", serve::sweep_json(&cfg, &reports));
}

#[test]
fn golden_serve_calibrated_matches_the_registry_path() {
    // The CLI spelling emits exactly the golden-gated calibrated bytes.
    let out = bertprof::scenario::run_by_name(
        "serve",
        &[
            ("requests".into(), "1000".into()),
            ("max-batches".into(), "1,8".into()),
            ("cost_table".into(), "examples/cost_table_mi100.json".into()),
            ("threads".into(), "2".into()),
        ],
        true,
    )
    .expect("calibrated serve runs");
    check("serve_calibrated", out.artifact);
}

#[test]
fn golden_decode_sweep() {
    let cfg = decode_golden_cfg();
    let reports = serve::run_decode_sweep(&cfg, 2);
    let artifact = serve::decode_sweep_json(&cfg, &reports);
    // The ISSUE 6 acceptance shape rides inside the snapshot: at least
    // one swept point where continuous batching strictly wins.
    let wins = artifact
        .get("verdicts")
        .expect("verdicts array")
        .as_arr()
        .expect("array")
        .iter()
        .filter(|v| matches!(v.get("continuous_wins"), Some(Json::Bool(true))))
        .count();
    assert!(wins >= 1, "no continuous-batching win on the golden grid");
    check("decode_sweep", artifact);
}

#[test]
fn golden_decode_matches_the_registry_path() {
    // `bertprof run decode --set requests=500` emits exactly the
    // golden-gated artifact (the CI scenario-artifacts row).
    let out = bertprof::scenario::run_by_name(
        "decode",
        &[("requests".into(), "500".into()), ("threads".into(), "2".into())],
        true,
    )
    .expect("decode runs");
    check("decode_sweep", out.artifact);
}

#[test]
fn golden_fleet_sweep() {
    let cfg = fleet_golden_cfg();
    let reports = serve::run_fleet_sweep(&cfg, 2);
    let artifact = serve::fleet_sweep_json(&cfg, &reports);
    // The ISSUE acceptance shape rides inside the snapshot: (a) at
    // least one heterogeneous-pool point where SLO-aware
    // power-of-two-choices beats round-robin on p99, and (b) at least
    // one diurnal point where the autoscaler saves replica-seconds at
    // equal (±2pp) SLO attainment.
    let arr = |key: &str| {
        artifact
            .get(key)
            .unwrap_or_else(|| panic!("{key} array"))
            .as_arr()
            .expect("array")
            .to_vec()
    };
    let p2c_wins = arr("verdicts")
        .iter()
        .filter(|v| {
            v.get("point")
                .and_then(|p| p.as_str())
                .is_some_and(|p| p.starts_with("hetero"))
                && matches!(v.get("p2c_wins"), Some(Json::Bool(true)))
        })
        .count();
    assert!(p2c_wins >= 1, "p2c never beat rr on p99 over the hetero pool");
    let auto_saves = arr("autoscale_verdicts")
        .iter()
        .filter(|v| {
            v.get("point")
                .and_then(|p| p.as_str())
                .is_some_and(|p| p.contains("diurnal"))
                && matches!(v.get("saves_replica_seconds"), Some(Json::Bool(true)))
                && matches!(v.get("holds_slo"), Some(Json::Bool(true)))
        })
        .count();
    assert!(
        auto_saves >= 1,
        "autoscaling never saved replica-seconds at equal SLO on a diurnal point"
    );
    check("fleet_sweep", artifact);
}

#[test]
fn golden_fleet_matches_the_registry_path() {
    // `bertprof run fleet --set requests=2000` emits exactly the
    // golden-gated artifact (the CI scenario-artifacts row).
    let out = bertprof::scenario::run_by_name(
        "fleet",
        &[("requests".into(), "2000".into()), ("threads".into(), "2".into())],
        true,
    )
    .expect("fleet runs");
    check("fleet_sweep", out.artifact);
}

#[test]
fn golden_cli_surface() {
    // `bertprof list --json` — the machine-readable CLI surface. A
    // mismatch means a scenario or parameter changed without its
    // snapshot (regenerate with UPDATE_GOLDEN=1 and review like any
    // interface change).
    check("cli_surface", bertprof::scenario::registry_json());
}

#[test]
fn golden_compress_sweep() {
    let cfg = compress_golden_cfg();
    let reports = compress::run_sweep(&cfg, 2);
    check("compress_sweep", compress::compress_json(&cfg, &reports));
}

/// The reduced successive-halving budget the snapshot pins: the full
/// 576-candidate default space, 3 rungs, 480 final-rung requests —
/// search mechanics, shared-cache stats, frontier, and the
/// cheapest-meeting-SLO verdict all ride inside the snapshot.
fn pareto_golden_cfg() -> bertprof::scenario::pareto::ParetoSearchConfig {
    let mut cfg = bertprof::scenario::pareto::ParetoSearchConfig::bert_large_default();
    cfg.requests = 480;
    cfg.rungs = 3;
    cfg
}

#[test]
fn golden_pareto_search() {
    let cfg = pareto_golden_cfg();
    let (outcome, cost) = bertprof::scenario::pareto::run_search(&cfg, 2);
    let artifact = bertprof::scenario::pareto::pareto_json(&cfg, &outcome, &cost);
    // The ISSUE acceptance shape rides inside the snapshot: a
    // >200-candidate space, a shared-cache hit rate above one half,
    // and a verdict that actually meets the SLO.
    assert!(outcome.candidates >= 200, "space too small: {}", outcome.candidates);
    assert!(
        cost.dedup_rate() > 0.5,
        "cache hit rate {:.2} under the acceptance bar",
        cost.dedup_rate()
    );
    let verdict = artifact.get("cheapest_meeting_slo").expect("verdict key");
    assert!(
        !matches!(verdict, Json::Null),
        "something must meet the 100 ms SLO on the default space"
    );
    check("pareto_search", artifact);
}

#[test]
fn golden_pareto_matches_the_registry_path() {
    // `bertprof run pareto --set requests=480 --set rungs=3` emits
    // exactly the golden-gated artifact (the CI scenario-artifacts row).
    let out = bertprof::scenario::run_by_name(
        "pareto",
        &[
            ("requests".into(), "480".into()),
            ("rungs".into(), "3".into()),
            ("threads".into(), "2".into()),
        ],
        true,
    )
    .expect("pareto runs");
    check("pareto_search", out.artifact);
}

#[test]
fn golden_gridscale() {
    // The reduced engine-scale grid the snapshot pins: 2000 requested
    // cells -> 28 replica planes x 72 combos = 2016 cells, 2 workers.
    // Everything but the wall-clock `timing` block (which the
    // comparator skips) is deterministic at any thread count.
    let cfg = bertprof::scenario::gridscale::GridScaleConfig::default_with_cells(2_000);
    let out = bertprof::scenario::gridscale::run_gridscale(&cfg, 2);
    // The ISSUE acceptance shape rides inside the snapshot: repeated
    // planes make the shared cache dedup the overwhelming majority of
    // its lookups, and the intern builds each distinct graph once.
    assert!(out.cache_dedup > 0.9, "dedup {:.3} under the bar", out.cache_dedup);
    assert_eq!(out.intern.misses as usize, out.intern.entries);
    check("gridscale", bertprof::scenario::gridscale::gridscale_json(&cfg, &out, 2));
}

#[test]
fn golden_gridscale_matches_the_registry_path() {
    // `bertprof run gridscale --set cells=2000 --set threads=2` emits
    // exactly the golden-gated artifact (the CI scenario-artifacts row).
    let out = bertprof::scenario::run_by_name(
        "gridscale",
        &[("cells".into(), "2000".into()), ("threads".into(), "2".into())],
        true,
    )
    .expect("gridscale runs");
    check("gridscale", out.artifact);
}

#[test]
fn golden_artifacts_are_run_to_run_stable() {
    // The "two consecutive runs" acceptance shape, in-process: every
    // artifact is byte-identical when recomputed.
    let dev = DeviceSpec::mi100();
    assert_eq!(
        artifact::fig04_json(&dev).to_string(),
        artifact::fig04_json(&dev).to_string()
    );
    let cfg = serve_golden_cfg();
    let a = serve::sweep_json(&cfg, &serve::run_sweep(&cfg, 1)).to_string();
    let b = serve::sweep_json(&cfg, &serve::run_sweep(&cfg, 3)).to_string();
    assert_eq!(a, b);
    let ccfg = compress_golden_cfg();
    let c = compress::compress_json(&ccfg, &compress::run_sweep(&ccfg, 1)).to_string();
    let d = compress::compress_json(&ccfg, &compress::run_sweep(&ccfg, 3)).to_string();
    assert_eq!(c, d);
}
