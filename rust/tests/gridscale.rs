//! Engine-scale stress tests for the sharded cache, the chunked
//! executor, and graph interning (the SSGridScale tentpole).
//!
//! The contract under load: a ≥10k-cell grid priced at {1, 2, 8, 32}
//! worker threads produces *identical* per-cell outputs and *identical*
//! cache accounting — not merely identical totals, but the same
//! hit/miss split, because the compute-under-lock miss path prices each
//! distinct key exactly once regardless of scheduling. And an interned
//! pruned graph must be op-for-op the graph a fresh, intern-free
//! rebuild produces — the table memoizes construction, never changes
//! its result.

use std::sync::Arc;

use bertprof::compress::PruneSpec;
use bertprof::config::Precision;
use bertprof::model::{GraphIntern, GraphKey, IterationGraph};
use bertprof::perf::{CacheStats, Cached, CostCache, CostModel, RooflinePricer};
use bertprof::scenario::exec;
use bertprof::scenario::gridscale::{grid_cells, run_gridscale, GridCell, GridScaleConfig};
use bertprof::serve::graph::inference_run;

/// Price every cell of `cfg`'s grid through one shared sharded table,
/// returning the raw per-cell outputs plus the table's final split.
fn price_grid(
    cfg: &GridScaleConfig,
    threads: usize,
    chunked: bool,
) -> (Vec<f64>, CacheStats) {
    let grid = grid_cells(cfg);
    let table = Arc::new(CostCache::for_threads(threads));
    let intern = Arc::new(GraphIntern::new());
    let cell_fn = |cell: &GridCell| {
        let run = inference_run(cfg.model, cell.batch, cfg.seq_len, cell.precision);
        let g = intern
            .get_or_build(GraphKey::base(&run, 0), || IterationGraph::build_inference(&run));
        let pricer = Cached::with_table(
            RooflinePricer::new(cfg.devices[cell.device].clone(), cell.precision),
            Arc::clone(&table),
        );
        (cell.replicas * cell.batch) as f64 / pricer.iteration_seconds(&g)
    };
    let out = if chunked {
        exec::run_grid(&grid, threads, cell_fn)
    } else {
        exec::run_grid_cell_stride(&grid, threads, cell_fn)
    };
    (out, table.stats())
}

#[test]
fn ten_k_cell_grid_is_exact_at_every_thread_count() {
    // 10_000 requested -> 139 replica planes -> 10_008 cells.
    let cfg = GridScaleConfig::default_with_cells(10_000);
    assert!(cfg.total_cells() >= 10_000);
    let (base_out, base_stats) = price_grid(&cfg, 1, true);
    assert_eq!(base_out.len(), cfg.total_cells() as usize);
    // Single-threaded ground truth: every lookup past the first plane's
    // misses is a hit, and misses == resident entries.
    assert_eq!(base_stats.misses as usize, base_stats.entries);
    assert!(base_stats.hits > base_stats.misses, "{base_stats:?}");
    for threads in [2usize, 8, 32] {
        let (out, stats) = price_grid(&cfg, threads, true);
        assert_eq!(out, base_out, "outputs drifted at {threads} threads");
        // The full split — not just the total — is scheduling-
        // independent; only the shard count varies with `threads`.
        assert_eq!(stats.hits, base_stats.hits, "{threads} threads");
        assert_eq!(stats.misses, base_stats.misses, "{threads} threads");
        assert_eq!(stats.entries, base_stats.entries, "{threads} threads");
        assert_eq!(stats.lookups(), base_stats.lookups());
    }
}

#[test]
fn chunked_and_cell_stride_executors_agree_under_load() {
    let cfg = GridScaleConfig::default_with_cells(10_000);
    let (chunked, chunked_stats) = price_grid(&cfg, 8, true);
    let (strided, strided_stats) = price_grid(&cfg, 8, false);
    assert_eq!(chunked, strided);
    assert_eq!(chunked_stats, strided_stats);
}

#[test]
fn gridscale_outcome_is_thread_count_invariant() {
    let cfg = GridScaleConfig::default_with_cells(10_000);
    let base = run_gridscale(&cfg, 1);
    for threads in [2usize, 8, 32] {
        let o = run_gridscale(&cfg, threads);
        assert_eq!(o.checksum, base.checksum, "{threads} threads");
        assert_eq!(o.min_throughput, base.min_throughput);
        assert_eq!(o.max_throughput, base.max_throughput);
        assert_eq!(o.cache.hits, base.cache.hits);
        assert_eq!(o.cache.misses, base.cache.misses);
        assert_eq!(o.intern, base.intern);
    }
    // One graph per distinct (device-independent) combo: precisions x
    // batches; every replica plane reuses them.
    assert_eq!(base.intern.requests(), cfg.total_cells());
    assert!(base.intern.entries < base.intern.requests() as usize);
}

#[test]
fn interned_pruned_graph_equals_a_fresh_rebuild() {
    let run = inference_run(
        bertprof::config::ModelConfig::bert_large(),
        8,
        128,
        Precision::Mixed,
    );
    let spec = PruneSpec::dense(&run.model).keep_heads(8).keep_ff(2048);

    let intern = GraphIntern::new();
    let key = GraphKey::base(&run, 0);
    let base = intern.get_or_build(key, || IterationGraph::build_inference(&run));
    let pruned = intern.get_or_build(key.pruned(spec), || spec.apply(&run.model, &base));

    // Intern-free ground truth: the table memoizes construction, never
    // alters its result.
    let fresh = spec.apply(&run.model, &IterationGraph::build_inference(&run));
    assert_eq!(pruned.ops, fresh.ops, "interned pruned graph diverged from rebuild");

    // A second request is served from the table — same allocation, no
    // rebuild (the closure would panic).
    let again = intern.get_or_build(key.pruned(spec), || unreachable!("must not rebuild"));
    assert!(Arc::ptr_eq(&pruned, &again));
    assert_eq!(intern.stats().entries, 2);
    assert_eq!(intern.stats().hits, 1);
}
