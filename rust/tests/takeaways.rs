//! Table 1 — every paper takeaway as an executable assertion over the
//! analytical stack. One test per takeaway (T1..T15); each comment
//! quotes the claim being checked.

use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::dist::{DataParallelModel, LinkSpec, ModelParallelModel};
use bertprof::model::gemm::table3;
use bertprof::model::lamb;
use bertprof::model::op::{LayerClass, OpCategory};
use bertprof::model::IterationGraph;
use bertprof::perf::device::DeviceSpec;
use bertprof::perf::roofline::{estimate_graph, estimate_op};

fn run(b: u64, prec: Precision) -> RunConfig {
    RunConfig::new(ModelConfig::bert_large().with_batch(b), Phase::Phase1, prec)
}

fn layer_fraction(r: &RunConfig, layer: LayerClass) -> f64 {
    let g = IterationGraph::build(r);
    let dev = DeviceSpec::mi100();
    let times = estimate_graph(&g, &dev, r.precision);
    let total: f64 = times.iter().map(|(_, t)| t).sum();
    times.iter().filter(|(o, _)| o.layer == layer).map(|(_, t)| t).sum::<f64>() / total
}

fn category_fraction(r: &RunConfig, pred: impl Fn(OpCategory) -> bool) -> f64 {
    let g = IterationGraph::build(r);
    let dev = DeviceSpec::mi100();
    let times = estimate_graph(&g, &dev, r.precision);
    let total: f64 = times.iter().map(|(_, t)| t).sum();
    times.iter().filter(|(o, _)| pred(o.category)).map(|(_, t)| t).sum::<f64>() / total
}

#[test]
fn t01_transformer_layers_dominate_everything_else_negligible() {
    // "Transformer layers dominate training time; output & embedding
    // layers have negligible contribution."
    let r = run(32, Precision::Fp32);
    assert!(layer_fraction(&r, LayerClass::Transformer) > 0.6);
    assert!(layer_fraction(&r, LayerClass::OutputLayer) < 0.05);
    assert!(layer_fraction(&r, LayerClass::Embedding) < 0.01);
}

#[test]
fn t02_lamb_second_highest_and_grows_with_fewer_tokens() {
    let lamb32 = layer_fraction(&run(32, Precision::Fp32), LayerClass::Optimizer);
    let lamb4 = layer_fraction(&run(4, Precision::Fp32), LayerClass::Optimizer);
    // Second-highest contributor at B=32 (7-20% per SS3.2.3).
    assert!(lamb32 > 0.07 && lamb32 < 0.20, "{lamb32}");
    assert!(lamb32 > layer_fraction(&run(32, Precision::Fp32), LayerClass::OutputLayer));
    // Grows as token count shrinks.
    assert!(lamb4 > 1.5 * lamb32);
}

#[test]
fn t03_lamb_more_important_under_mixed_precision() {
    let f = layer_fraction(&run(32, Precision::Fp32), LayerClass::Optimizer);
    let m = layer_fraction(&run(32, Precision::Mixed), LayerClass::Optimizer);
    assert!(m > f, "mp {m} fp32 {f}");
    // Absolute LAMB bytes identical (FP32 master copies).
    let bytes = |p| -> u64 {
        lamb::lamb_ops(&run(32, p)).iter().map(|o| o.total_bytes()).sum()
    };
    assert_eq!(bytes(Precision::Fp32), bytes(Precision::Mixed));
}

#[test]
fn t04_linear_and_fc_gemms_dominate_transformer_time() {
    // "~57% of iteration runtime in FP32, ~40% in MP" for linear + FC.
    let frac32 = category_fraction(&run(32, Precision::Fp32), |c| {
        matches!(c, OpCategory::LinearGemm | OpCategory::FcGemm)
    });
    let frac_mp = category_fraction(&run(32, Precision::Mixed), |c| {
        matches!(c, OpCategory::LinearGemm | OpCategory::FcGemm)
    });
    assert!(frac32 > 0.45 && frac32 < 0.72, "{frac32}");
    assert!(frac_mp > 0.28 && frac_mp < 0.55, "{frac_mp}");
    assert!(frac_mp < frac32);
}

#[test]
fn t05_non_gemm_ops_grow_in_importance_at_reduced_precision() {
    let non_gemm = |p| category_fraction(&run(32, p), |c| !c.is_gemm());
    assert!(non_gemm(Precision::Mixed) > non_gemm(Precision::Fp32) + 0.05);
}

#[test]
fn t06_no_matrix_vector_ops_at_batch_one() {
    for row in table3(&ModelConfig::bert_large().with_batch(1)) {
        for g in [row.fwd, row.bwd_dgrad, row.bwd_wgrad] {
            assert!(g.m > 1 && g.n > 1 && g.k > 1, "{g:?}");
        }
    }
}

#[test]
fn t07_not_all_gemms_equal_attention_bgemms_memory_bound() {
    let dev = DeviceSpec::mi100();
    let t3 = table3(&ModelConfig::bert_large());
    let eb = 4;
    // FC GEMM ops/byte >> attention B-GEMM ops/byte.
    assert!(t3[3].fwd.ops_per_byte(eb) > 5.0 * t3[1].fwd.ops_per_byte(eb));
    assert!(bertprof::perf::gemm_model::is_memory_bound(&t3[1].fwd, &dev, Precision::Fp32));
    assert!(!bertprof::perf::gemm_model::is_memory_bound(&t3[3].fwd, &dev, Precision::Fp32));
}

#[test]
fn t08_lamb_reads_4x_model_size() {
    let m = lamb::lamb_read_multiple(&run(32, Precision::Fp32));
    assert!(m > 3.9 && m < 4.1, "{m}");
}

#[test]
fn t09_memory_bound_ops_are_30_to_40_pct_of_fp32_runtime() {
    let r = run(32, Precision::Fp32);
    let g = IterationGraph::build(&r);
    let dev = DeviceSpec::mi100();
    let mut mem = 0.0;
    let mut total = 0.0;
    for op in &g.ops {
        let t = estimate_op(op, &dev, r.precision);
        total += t.seconds * op.count as f64;
        if t.memory_bound {
            mem += t.seconds * op.count as f64;
        }
    }
    let frac = mem / total;
    assert!(frac > 0.25 && frac < 0.50, "{frac}");
}

#[test]
fn t10_memory_bound_share_grows_to_half_under_mp() {
    let frac = |p: Precision| {
        let r = run(32, p);
        let g = IterationGraph::build(&r);
        let dev = DeviceSpec::mi100();
        let mut mem = 0.0;
        let mut total = 0.0;
        for op in &g.ops {
            let t = estimate_op(op, &dev, r.precision);
            total += t.seconds * op.count as f64;
            if t.memory_bound {
                mem += t.seconds * op.count as f64;
            }
        }
        mem / total
    };
    assert!(frac(Precision::Mixed) > frac(Precision::Fp32) + 0.08);
    assert!(frac(Precision::Mixed) > 0.40, "{}", frac(Precision::Mixed));
}

#[test]
fn t11_fewer_tokens_raise_memory_intensive_share() {
    let ew = |b| category_fraction(&run(b, Precision::Fp32), |c| {
        matches!(c, OpCategory::LambStage1 | OpCategory::LambStage2
                 | OpCategory::LambNorm | OpCategory::DrResLn | OpCategory::Gelu)
    });
    assert!(ew(4) > ew(32));
    // Sequence-length shrink has the same effect.
    let mut short = run(32, Precision::Fp32);
    short.model.seq_len = 64;
    let ew_short = category_fraction(&short, |c| {
        matches!(c, OpCategory::LambStage1 | OpCategory::LambStage2
                 | OpCategory::LambNorm | OpCategory::DrResLn | OpCategory::Gelu)
    });
    assert!(ew_short > ew(32));
}

#[test]
fn t12_transformer_and_lamb_scale_linearly_with_layer_count() {
    let time = |n: u64, layer: LayerClass| -> f64 {
        let r = RunConfig::new(ModelConfig::bert_large().with_layers(n),
                               Phase::Phase1, Precision::Fp32);
        let g = IterationGraph::build(&r);
        let dev = DeviceSpec::mi100();
        estimate_graph(&g, &dev, r.precision)
            .iter()
            .filter(|(o, _)| o.layer == layer)
            .map(|(_, t)| t)
            .sum()
    };
    for layer in [LayerClass::Transformer, LayerClass::Optimizer] {
        let r = time(48, layer) / time(24, layer);
        assert!(r > 1.85 && r < 2.15, "{layer:?} {r}");
    }
    // Their combined fraction grows slightly (embedding/output constant).
    let lf = |n: u64| {
        let r = RunConfig::new(ModelConfig::bert_large().with_layers(n),
                               Phase::Phase1, Precision::Fp32);
        layer_fraction(&r, LayerClass::Transformer)
            + layer_fraction(&r, LayerClass::Optimizer)
    };
    assert!(lf(48) >= lf(24));
}

#[test]
fn t13_wider_models_raise_gemm_and_lamb_proportion() {
    let base = run(32, Precision::Fp32);
    let wide = RunConfig::new(ModelConfig::bert_large().with_width(2048),
                              Phase::Phase1, Precision::Fp32);
    let gemm = |r: &RunConfig| category_fraction(r, |c| {
        matches!(c, OpCategory::LinearGemm | OpCategory::FcGemm)
    });
    assert!(gemm(&wide) > gemm(&base) - 0.02); // GEMMs hold/grow
    assert!(layer_fraction(&wide, LayerClass::Optimizer)
            > layer_fraction(&base, LayerClass::Optimizer));
}

#[test]
fn t14_data_parallel_breakdown_matches_single_device() {
    let dev = DeviceSpec::mi100();
    let r = run(16, Precision::Fp32);
    let dp = DataParallelModel::new(64, LinkSpec::pcie4x16(), true).breakdown(&r, &dev);
    let single = DataParallelModel::new(1, LinkSpec::pcie4x16(), true).breakdown(&r, &dev);
    // Comm mostly hidden; compute mix unchanged.
    assert!(dp.comm_fraction() < 0.08, "{}", dp.comm_fraction());
    let mix = |b: &bertprof::dist::DistBreakdown| b.lamb / (b.total() - b.comm_exposed);
    assert!((mix(&dp) - mix(&single)).abs() < 0.01);
}

#[test]
fn t15_model_parallel_shrinks_lamb_but_grows_serialized_comm() {
    let dev = DeviceSpec::mi100();
    let link = LinkSpec::pcie4x16();
    let single = ModelParallelModel::new(1, link.clone())
        .breakdown(&run(16, Precision::Fp32), &dev);
    let m2 = ModelParallelModel::new(2, link.clone())
        .breakdown(&run(16, Precision::Fp32), &dev);
    let m8 = ModelParallelModel::new(8, link.clone())
        .breakdown(&run(64, Precision::Fp32), &dev);
    assert!(m2.lamb_fraction() < single.lamb_fraction());
    assert!(m8.lamb_fraction() < m2.lamb_fraction());
    assert!(m8.comm_fraction() > m2.comm_fraction());
    // Comm volume grows with model parallelism (larger batch).
    let mp2 = ModelParallelModel::new(2, link.clone());
    let mp8 = ModelParallelModel::new(8, link);
    assert!(mp8.comm_volume(&run(64, Precision::Fp32))
            > mp2.comm_volume(&run(16, Precision::Fp32)));
}
