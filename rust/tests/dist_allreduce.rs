//! Property tests for the ring-collective models (randomized with the
//! in-tree PRNG, like `properties.rs`): the `2*(D-1)/D` ring factor is
//! exact for volumes and a lower bound for times, both halves compose to
//! the whole, and the distributed breakdowns stay well-formed across
//! random configurations. Complements the bounds already asserted in
//! `properties.rs::prop_allreduce_volume_bounded_by_2x_payload`.

use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::dist::allreduce::{
    all_gather_time, reduce_scatter_time, ring_allreduce_steps, ring_allreduce_time,
    ring_allreduce_volume,
};
use bertprof::dist::{
    DataParallelModel, HybridModel, LinkSpec, ModelParallelModel, ZeroModel,
};
use bertprof::perf::device::DeviceSpec;
use bertprof::util::Rng;

#[test]
fn prop_volume_monotone_in_payload() {
    let mut rng = Rng::seed(41);
    for _ in 0..300 {
        let a = rng.int_range(0, 1 << 32) as u64;
        let b = a + rng.int_range(0, 1 << 24) as u64;
        let d = rng.int_range(1, 512) as u64;
        assert!(
            ring_allreduce_volume(a, d) <= ring_allreduce_volume(b, d),
            "payload {a}->{b} devices {d}"
        );
    }
}

#[test]
fn volume_matches_hand_computed_points() {
    // Independent oracle: ring volumes worked out by hand.
    let gib = 1u64 << 30;
    assert_eq!(ring_allreduce_volume(gib, 2), gib); // 2*(1/2)*b
    assert_eq!(ring_allreduce_volume(gib, 4), 3 * gib / 2); // 2*(3/4)*b
    assert_eq!(ring_allreduce_volume(1000, 10), 1800); // 2*(9/10)*1000
    assert_eq!(ring_allreduce_volume(7, 7), 12); // floor(2*6*7/7)
    assert_eq!(ring_allreduce_steps(8), 14); // (D-1) RS + (D-1) AG
}

#[test]
fn prop_volume_equals_per_step_chunk_sum() {
    // Independent oracle: the ring runs 2*(D-1) steps each sending one
    // ~1/D chunk, so the volume must sit within one chunk-rounding of
    // 2*(D-1)*floor(b/D).
    let mut rng = Rng::seed(42);
    for _ in 0..300 {
        let bytes = rng.int_range(1, 1 << 32) as u64;
        let d = rng.int_range(2, 512) as u64;
        let v = ring_allreduce_volume(bytes, d);
        let chunked = 2 * (d - 1) * (bytes / d);
        assert!(
            v >= chunked && v <= chunked + 2 * (d - 1),
            "b={bytes} D={d}: {v} vs {chunked}"
        );
    }
}

#[test]
fn prop_time_lower_bounded_by_ring_bandwidth_term() {
    // T(b, D) >= (2*(D-1)/D) * b / bandwidth — latency only adds; and
    // doubling the device count never shrinks the time (the 2(N-1)/N
    // factor and the step count both grow).
    let link = LinkSpec::pcie4x16();
    let mut rng = Rng::seed(43);
    for _ in 0..300 {
        let bytes = rng.int_range(1, 1 << 32) as u64;
        let n = rng.int_range(2, 256) as u64;
        let t = ring_allreduce_time(bytes, n, &link);
        let d = n as f64;
        let bw_floor = (2.0 * (d - 1.0) / d) * bytes as f64 / link.bandwidth;
        assert!(t >= bw_floor, "{t} < {bw_floor}");
        let t2 = ring_allreduce_time(bytes, 2 * n, &link);
        assert!(t2 >= t - 1e-12, "D={n}: {t2} < {t}");
        // The factor saturates: time at 2N never exceeds the latency
        // steps plus the full 2x-payload traversal.
        let ceil = 2.0 * (2.0 * d - 1.0) * link.latency
            + 2.0 * bytes as f64 / link.bandwidth;
        assert!(t2 <= ceil, "{t2} > {ceil}");
    }
}

#[test]
fn prop_reduce_scatter_plus_all_gather_is_the_allreduce() {
    let link = LinkSpec::xgmi();
    let mut rng = Rng::seed(44);
    for _ in 0..300 {
        let bytes = rng.int_range(1, 1 << 32) as u64;
        let d = rng.int_range(1, 512) as u64;
        let whole = ring_allreduce_time(bytes, d, &link);
        let halves = reduce_scatter_time(bytes, d, &link) + all_gather_time(bytes, d, &link);
        assert!(
            (whole - halves).abs() <= 1e-9 * whole.max(1e-12),
            "D={d}: {whole} vs {halves}"
        );
    }
}

#[test]
fn prop_breakdowns_are_well_formed_for_random_configs() {
    let dev = DeviceSpec::mi100();
    let link = LinkSpec::pcie4x16();
    let mut rng = Rng::seed(45);
    for _ in 0..20 {
        let b = [4u64, 8, 16, 32][rng.int_range(0, 3) as usize];
        let run = RunConfig::new(
            ModelConfig::bert_large().with_batch(b),
            Phase::Phase1,
            if rng.uniform() < 0.5 { Precision::Fp32 } else { Precision::Mixed },
        );
        let d = [2u64, 4, 8, 64, 256][rng.int_range(0, 4) as usize];
        let rows = [
            DataParallelModel::new(d, link.clone(), true).breakdown(&run, &dev),
            DataParallelModel::new(d, link.clone(), false).breakdown(&run, &dev),
            ModelParallelModel::new(d.min(16), link.clone()).breakdown(&run, &dev),
            HybridModel::megatron_128().breakdown(&run, &dev),
            ZeroModel::new(d, link.clone()).breakdown(&run, &dev),
        ];
        for bd in rows {
            assert!(bd.total() > 0.0 && bd.total().is_finite(), "{}", bd.label);
            assert!(bd.comm_exposed >= 0.0, "{}", bd.label);
            let share_sum = bd.lamb_fraction()
                + bd.comm_fraction()
                + (bd.transformer + bd.output + bd.embedding) / bd.total();
            assert!((share_sum - 1.0).abs() < 1e-9, "{}: {share_sum}", bd.label);
        }
    }
}

#[test]
fn prop_overlap_never_beats_free_and_never_loses_to_serial() {
    let dev = DeviceSpec::mi100();
    let link = LinkSpec::pcie4x16();
    let mut rng = Rng::seed(46);
    for _ in 0..20 {
        let b = [4u64, 16, 32][rng.int_range(0, 2) as usize];
        let run = RunConfig::new(
            ModelConfig::bert_large().with_batch(b),
            Phase::Phase1,
            Precision::Fp32,
        );
        let d = rng.int_range(2, 512) as u64;
        let base = DataParallelModel::new(1, link.clone(), true).breakdown(&run, &dev);
        let ov = DataParallelModel::new(d, link.clone(), true).breakdown(&run, &dev);
        let sr = DataParallelModel::new(d, link.clone(), false).breakdown(&run, &dev);
        assert!(ov.total() >= base.total() - 1e-12, "D={d}");
        assert!(ov.total() <= sr.total() + 1e-12, "D={d}");
    }
}
