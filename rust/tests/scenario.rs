//! Integration tests for the scenario engine (ISSUE 4 acceptance):
//! the registry path produces byte-identical artifacts to the
//! pre-refactor subcommand plumbing, the shared `CostCache` changes no
//! modeled time anywhere, and the one executor keeps every grid
//! deterministic across worker counts.

use std::sync::Arc;

use bertprof::compress::{self, CompressPrecision, CompressSweepConfig, CompressVariant};
use bertprof::config::{Precision, RunConfig};
use bertprof::model::IterationGraph;
use bertprof::perf::device::DeviceSpec;
use bertprof::perf::{roofline, Cached, CostCache, CostModel, RooflinePricer};
use bertprof::profiler::{artifact, Timeline};
use bertprof::scenario::{self, exec};
use bertprof::serve::{self, SweepConfig};

fn pairs(kv: &[(&str, &str)]) -> Vec<(String, String)> {
    kv.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

#[test]
fn registry_covers_every_experiment_index_row() {
    // The DESIGN.md experiment-index "scenario name" column — one name
    // per analytic experiment (runtime-backed `train`/`export` excepted).
    let names: Vec<&str> = scenario::registry().iter().map(|s| s.name).collect();
    assert!(names.len() >= 14, "{names:?}");
    for n in [
        "fig04", "fig05", "fig07", "fig08", "fig09", "fig10", "fig12", "fig13", "fig15",
        "table3", "memory", "whatif", "serve", "compress",
    ] {
        assert!(names.contains(&n), "{n}");
    }
}

#[test]
fn run_serve_is_byte_identical_to_the_pre_refactor_sweep() {
    // The acceptance criterion, at the golden snapshot's grid: the
    // registry path (`bertprof run serve --set ...`) and the direct
    // SweepConfig path emit the same bytes.
    let out = scenario::run_by_name(
        "serve",
        &pairs(&[("requests", "1000"), ("max-batches", "1,8"), ("threads", "3")]),
        true,
    )
    .unwrap();
    let mut cfg = SweepConfig::bert_large_default();
    cfg.requests = 1_000;
    cfg.max_batches = vec![1, 8];
    let direct = serve::sweep_json(&cfg, &serve::run_sweep(&cfg, 1));
    assert_eq!(out.artifact.to_string(), direct.to_string());
}

#[test]
fn run_compress_is_byte_identical_to_the_pre_refactor_sweep() {
    let out = scenario::run_by_name(
        "compress",
        &pairs(&[
            ("requests", "800"),
            ("device", "mi100"),
            ("max-batch", "32"),
            ("threads", "2"),
        ]),
        true,
    )
    .unwrap();
    let mut cfg = CompressSweepConfig::bert_large_default();
    cfg.requests = 800;
    cfg.devices = vec![DeviceSpec::mi100()];
    cfg.max_batches = vec![32];
    let direct = compress::compress_json(&cfg, &compress::run_sweep(&cfg, 1));
    // Note: the golden compress snapshot pins a reduced 3-variant
    // ladder; here both paths use the default 6-variant ladder — the
    // point is registry == direct, byte for byte.
    assert_eq!(out.artifact.to_string(), direct.to_string());
}

#[test]
fn cost_cache_changes_no_modeled_time_across_the_figure_grid() {
    // ISSUE acceptance: "a test proves the cache changes no modeled
    // time" — every fig04 config on every preset, op for op, with one
    // shared table spanning all (device, precision) pricers.
    let cost = Arc::new(CostCache::new());
    for dev in [
        DeviceSpec::mi100(),
        DeviceSpec::v100(),
        DeviceSpec::a100(),
        DeviceSpec::tpu_v3_core(),
        DeviceSpec::cpu_host(),
    ] {
        for run in RunConfig::figure4_set() {
            let g = IterationGraph::build(&run);
            let pricer = Cached::with_table(
                RooflinePricer::new(dev.clone(), run.precision),
                Arc::clone(&cost),
            );
            assert_eq!(
                roofline::iteration_seconds(&g, &dev, run.precision),
                pricer.iteration_seconds(&g),
                "{} {}",
                dev.name,
                run.label()
            );
            let plain = Timeline::modeled(&run, &dev);
            let cached = Timeline::modeled_with(&run, &pricer);
            for (a, b) in plain.entries.iter().zip(&cached.entries) {
                assert_eq!(a.seconds, b.seconds, "{} {}", dev.name, a.name);
            }
        }
    }
    assert!(cost.hit_rate() > 0.3, "figure grid should mostly hit: {}", cost.hit_rate());
}

#[test]
fn inference_ladder_survives_the_cache() {
    // The compress sweep's dense rungs run through the same cached
    // pricing; ladder order is a property of the model, not the memo.
    let cost = Arc::new(CostCache::new());
    let dev = DeviceSpec::mi100();
    let secs = |prec| {
        let run = bertprof::serve::inference_run(
            bertprof::config::ModelConfig::bert_large(),
            8,
            128,
            prec,
        );
        let g = bertprof::serve::forward_graph(&run, bertprof::serve::ServeHead::Squad);
        Cached::with_table(RooflinePricer::new(dev.clone(), prec), Arc::clone(&cost))
            .iteration_seconds(&g)
    };
    let f32t = secs(Precision::Fp32);
    let f16t = secs(Precision::Mixed);
    let i8t = secs(Precision::Int8);
    assert!(f16t < f32t && i8t <= f16t, "{f32t} {f16t} {i8t}");
}

#[test]
fn figure_scenarios_emit_the_golden_shaped_artifacts() {
    let dev = DeviceSpec::mi100();
    for (name, want) in [
        ("fig04", artifact::fig04_json(&dev)),
        ("fig07", artifact::fig07_json(&dev)),
        ("fig09", artifact::fig09_json(&dev)),
        ("fig12", artifact::fig12_json(&dev)),
    ] {
        let out = scenario::run_by_name(name, &[], true).unwrap();
        assert_eq!(out.artifact.to_string(), want.to_string(), "{name}");
        assert!(!out.text.is_empty(), "{name}");
    }
}

#[test]
fn executor_is_worker_count_invariant_on_a_compress_grid() {
    let mut cfg = CompressSweepConfig::bert_large_default();
    cfg.requests = 300;
    cfg.devices = vec![DeviceSpec::mi100()];
    cfg.max_batches = vec![8];
    cfg.variants = vec![
        CompressVariant::dense(&cfg.model, CompressPrecision::Fp32),
        CompressVariant::dense(&cfg.model, CompressPrecision::Int8Full),
    ];
    let a = compress::compress_json(&cfg, &compress::run_sweep(&cfg, 1)).to_string();
    let b = compress::compress_json(&cfg, &compress::run_sweep(&cfg, 16)).to_string();
    assert_eq!(a, b);
    // And the raw executor preserves grid order under oversubscription.
    let grid: Vec<u64> = (0..40).collect();
    let out = exec::run_grid(&grid, 64, |&x| x);
    assert_eq!(out, grid);
}
