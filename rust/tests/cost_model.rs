//! Decorator laws for the one `CostModel` API (ISSUE 5 acceptance):
//!
//! * `Cached<RooflinePricer>` is op-for-op bit-identical to the bare
//!   `RooflinePricer` across every registry scenario's graphs;
//! * an identity `CalibratedPricer` (empty table) matches the analytic
//!   backend exactly;
//! * the quantized and NMC decorators match their historical
//!   free-function spellings exactly;
//! * one shared `CostCache` table spans all of the above without
//!   cross-contamination (fingerprints keep pricers apart).

use std::sync::Arc;

use bertprof::compress::quant::{self, QuantConfig, QuantPricer};
use bertprof::compress::{CompressSweepConfig, CompressedLatencyModel};
use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::model::IterationGraph;
use bertprof::perf::device::DeviceSpec;
use bertprof::perf::whatif::{self, NmcPricer};
use bertprof::perf::{roofline, Cached, CalibratedPricer, CostCache, CostModel, RooflinePricer};
use bertprof::serve::{forward_graph, inference_run, BatchCost, ServeHead};

/// Every graph shape the scenario registry prices, labeled: the Fig. 4
/// config set (fig04/fig05/fig08 and the memory/whatif bases), the
/// fig09 batch points, the fig10 width points, the depth points, the
/// fig12 sharded graph, and the serve grid's forward graphs (serve and
/// the dense compress rungs).
fn registry_graphs() -> Vec<(String, IterationGraph, Precision)> {
    let mut out = Vec::new();
    // fig04/fig05/fig08/memory/whatif: the figure-4 config set.
    for run in RunConfig::figure4_set() {
        out.push((run.label(), IterationGraph::build(&run), run.precision));
    }
    // fig09 batches / fig10 widths / depth points (FP32 grids).
    for b in [4u64, 8, 16, 32] {
        let run = RunConfig::new(
            ModelConfig::bert_large().with_batch(b),
            Phase::Phase1,
            Precision::Fp32,
        );
        out.push((format!("fig09 B{b}"), IterationGraph::build(&run), run.precision));
    }
    for w in [512u64, 768, 1024, 1536, 2048] {
        let run = RunConfig::new(
            ModelConfig::bert_large().with_width(w),
            Phase::Phase1,
            Precision::Fp32,
        );
        out.push((format!("fig10 d{w}"), IterationGraph::build(&run), run.precision));
    }
    for n in [6u64, 12, 24, 48] {
        let run = RunConfig::new(
            ModelConfig::bert_large().with_layers(n),
            Phase::Phase1,
            Precision::Fp32,
        );
        out.push((format!("depth N{n}"), IterationGraph::build(&run), run.precision));
    }
    // fig12: the sharded-optimizer graph the dist models price.
    let run16 = RunConfig::new(
        ModelConfig::bert_large().with_batch(16),
        Phase::Phase1,
        Precision::Fp32,
    );
    out.push((
        "fig12 sharded-8".into(),
        IterationGraph::build_sharded(&run16, 8, 1),
        run16.precision,
    ));
    // serve / compress dense rungs: forward graphs at the padded shapes.
    for prec in [Precision::Fp32, Precision::Mixed, Precision::Int8] {
        for (b, s) in [(1u64, 32u64), (8, 128), (32, 128)] {
            let run = inference_run(ModelConfig::bert_large(), b, s, prec);
            out.push((
                format!("serve {} B{b} n{s}", prec.label()),
                forward_graph(&run, ServeHead::Squad),
                prec,
            ));
        }
    }
    out
}

#[test]
fn cached_roofline_is_op_for_op_identical_across_every_registry_graph() {
    let table = Arc::new(CostCache::new());
    for dev in [DeviceSpec::mi100(), DeviceSpec::v100()] {
        for (label, g, prec) in registry_graphs() {
            let bare = RooflinePricer::new(dev.clone(), prec);
            let cached = Cached::with_table(bare.clone(), Arc::clone(&table));
            for op in &g.ops {
                let a = bare.price_op(op);
                let b = cached.price_op(op);
                assert_eq!(a.seconds, b.seconds, "{} {} {}", dev.name, label, op.name);
                assert_eq!(
                    a.memory_bound, b.memory_bound,
                    "{} {} {}",
                    dev.name, label, op.name
                );
            }
            assert_eq!(
                bare.iteration_seconds(&g),
                cached.iteration_seconds(&g),
                "{} {label}",
                dev.name
            );
        }
    }
    // The grid genuinely exercised the memo (repeated shapes hit).
    assert!(table.hits() > table.misses(), "{} hits {} misses", table.hits(), table.misses());
}

#[test]
fn identity_calibrated_pricer_matches_the_analytic_backend() {
    for dev in [DeviceSpec::mi100(), DeviceSpec::a100()] {
        for (label, g, prec) in registry_graphs() {
            let bare = RooflinePricer::new(dev.clone(), prec);
            let ident = CalibratedPricer::identity(bare.clone());
            for op in &g.ops {
                assert_eq!(
                    bare.price_op(op).seconds,
                    ident.price_op(op).seconds,
                    "{} {} {}",
                    dev.name,
                    label,
                    op.name
                );
            }
            assert_eq!(bare.iteration_seconds(&g), ident.iteration_seconds(&g));
            // And cached-calibrated-identity too (two decorators deep).
            let stacked = Cached::new(CalibratedPricer::identity(bare.clone()));
            assert_eq!(bare.iteration_seconds(&g), stacked.iteration_seconds(&g));
        }
    }
}

#[test]
fn quant_pricer_matches_the_quant_free_functions() {
    let dev = DeviceSpec::mi100();
    for (q, prec) in [
        (QuantConfig::weight_only(), Precision::Mixed),
        (QuantConfig::int8(), Precision::Int8),
    ] {
        for (b, s) in [(1u64, 32u64), (8, 128), (32, 128)] {
            let run = inference_run(ModelConfig::bert_large(), b, s, prec);
            let g = forward_graph(&run, ServeHead::Squad);
            let pricer = QuantPricer::new(RooflinePricer::new(dev.clone(), prec), q);
            for op in &g.ops {
                assert_eq!(
                    quant::op_seconds(op, &dev, &q),
                    pricer.price_op(op).seconds,
                    "{} B{b} n{s} {}",
                    q.label(),
                    op.name
                );
            }
            assert_eq!(
                quant::iteration_seconds(&g, &dev, &q),
                pricer.iteration_seconds(&g)
            );
        }
    }
}

#[test]
#[should_panic(expected = "exec precision")]
fn quant_pricer_rejects_a_mismatched_inner_precision() {
    let _ = QuantPricer::new(
        RooflinePricer::new(DeviceSpec::mi100(), Precision::Fp32),
        QuantConfig::int8(),
    );
}

#[test]
fn nmc_pricer_matches_the_whatif_free_function() {
    let dev = DeviceSpec::mi100();
    let run = RunConfig::new(ModelConfig::bert_large(), Phase::Phase1, Precision::Fp32);
    let g = IterationGraph::build(&run);
    for k in [2.0, 4.0, 8.0] {
        let pricer = NmcPricer::new(RooflinePricer::new(dev.clone(), run.precision), k);
        assert_eq!(
            whatif::iteration_seconds_with_nmc(&g, &dev, run.precision, k),
            pricer.iteration_seconds(&g)
        );
    }
}

#[test]
fn compressed_latency_model_still_prices_through_the_quant_backend() {
    // The subsystem wrapper and the raw decorator agree — the sweep's
    // simulator sees exactly the trait's numbers.
    let cfg = CompressSweepConfig::bert_large_default();
    let dev = DeviceSpec::mi100();
    for variant in &cfg.variants {
        let mut lm = CompressedLatencyModel::new(cfg.model, variant, dev.clone());
        let pricer = quant::pricer(variant.precision, &dev);
        for (b, s) in [(1u64, 32u64), (8, 128), (32, 128)] {
            let run = inference_run(cfg.model, b, lm.padded_seq(s), variant.precision.exec_precision());
            let g = forward_graph(&run, ServeHead::Squad);
            let g = variant.prune.apply(&run.model, &g);
            assert_eq!(
                lm.batch_seconds(b, s),
                pricer.iteration_seconds(&g),
                "{} B{b} n{s}",
                variant.name
            );
        }
    }
}

#[test]
fn one_shared_table_keeps_distinct_pricers_apart() {
    // Roofline, calibrated, quantized, and NMC pricers all share one
    // table; every combination still prices exactly like its bare twin.
    let table = Arc::new(CostCache::new());
    let dev = DeviceSpec::mi100();
    let run = inference_run(ModelConfig::bert_large(), 8, 128, Precision::Int8);
    let g = forward_graph(&run, ServeHead::Squad);

    let base = RooflinePricer::new(dev.clone(), Precision::Int8);
    let cal = CalibratedPricer::new(
        base.clone(),
        bertprof::perf::CalibrationTable::empty().with("FC-GEMM", 1.3),
    );
    let qp = QuantPricer::new(base.clone(), QuantConfig::int8());
    let nmc = NmcPricer::new(base.clone(), 4.0);

    let want_base = base.iteration_seconds(&g);
    let want_cal = cal.iteration_seconds(&g);
    let want_q = qp.iteration_seconds(&g);
    let want_nmc = nmc.iteration_seconds(&g);
    assert!(want_cal > want_base && want_q != want_base && want_nmc < want_base);

    // Interleave cached pricing over the one table, twice (cold + warm).
    for _ in 0..2 {
        assert_eq!(
            Cached::with_table(base.clone(), Arc::clone(&table)).iteration_seconds(&g),
            want_base
        );
        assert_eq!(
            Cached::with_table(cal.clone(), Arc::clone(&table)).iteration_seconds(&g),
            want_cal
        );
        assert_eq!(
            Cached::with_table(qp.clone(), Arc::clone(&table)).iteration_seconds(&g),
            want_q
        );
        assert_eq!(
            Cached::with_table(nmc.clone(), Arc::clone(&table)).iteration_seconds(&g),
            want_nmc
        );
    }
    assert!(table.hits() > 0);
}

#[test]
fn roofline_free_functions_are_faithful_delegates() {
    // The compatibility surface prices exactly like the canonical
    // pricer (one kernel, two spellings).
    let dev = DeviceSpec::v100();
    let run = RunConfig::new(ModelConfig::bert_large(), Phase::Phase1, Precision::Mixed);
    let g = IterationGraph::build(&run);
    let pricer = RooflinePricer::new(dev.clone(), run.precision);
    assert_eq!(
        roofline::iteration_seconds(&g, &dev, run.precision),
        pricer.iteration_seconds(&g)
    );
    let graph_a = roofline::estimate_graph(&g, &dev, run.precision);
    let graph_b = pricer.price_graph(&g);
    assert_eq!(graph_a.len(), graph_b.len());
    for ((oa, ta), (ob, tb)) in graph_a.iter().zip(&graph_b) {
        assert_eq!(oa.name, ob.name);
        assert_eq!(ta, tb);
    }
}
