//! Property tests for the generative serving subsystem (DESIGN.md
//! SSDecode): Little's law re-integrated from raw continuous-batching
//! events (and FIFO events, via the shared helper), token conservation,
//! the decode-graph-at-cache-0 ≡ seq-1-forward-slice pricing identity,
//! exact KV-cache linearity, and seed/thread determinism of the sweep
//! artifact.

use std::sync::Arc;

use bertprof::config::{ModelConfig, Precision};
use bertprof::perf::device::DeviceSpec;
use bertprof::perf::{memory, Cached, CostModel, RooflinePricer};
use bertprof::serve::{
    decode_graph, decode_sweep_json, forward_graph, inference_run, run_decode_sweep, BatchCost,
    BatchPolicy, ContinuousBatchPolicy, DecodeModel, DecodeOutcome, DecodePolicy, DecodeSimulator,
    DecodeSweepConfig, DecodeWorkload, LatencyModel, ServeHead,
};
use bertprof::util::Rng;

mod common;

fn models(prec: Precision) -> (LatencyModel, DecodeModel) {
    (
        LatencyModel::new(ModelConfig::bert_large(), prec, DeviceSpec::mi100()),
        DecodeModel::new(ModelConfig::bert_large(), prec, DeviceSpec::mi100()),
    )
}

fn simulate(policy: DecodePolicy, rate: f64, requests: u64, seed: u64) -> DecodeOutcome {
    let (mut pf, mut dm) = models(Precision::Mixed);
    let trace = DecodeWorkload::poisson(rate, requests, seed).generate();
    DecodeSimulator::new(policy, 2.0).run("prop", &trace, &mut pf, &mut dm)
}

fn spans(out: &DecodeOutcome) -> Vec<(f64, f64)> {
    out.completions.iter().map(|c| (c.arrival, c.done)).collect()
}

#[test]
fn prop_littles_law_holds_for_both_schedulers() {
    // The hoisted invariant (tests/common): the same `L = λ·W` check the
    // encoder suite runs, here against FIFO lock-step decode AND
    // slot-based continuous batching, across random loads and sizes.
    let mut rng = Rng::seed(2025);
    for _ in 0..4 {
        let rate = 5.0 + 20.0 * rng.uniform();
        let size = rng.int_range(1, 24) as u64;
        let seed = rng.next_u64();
        for policy in [
            DecodePolicy::Fifo(BatchPolicy::new(size, 0.010)),
            DecodePolicy::Continuous(ContinuousBatchPolicy::new(size)),
        ] {
            let out = simulate(policy, rate, 800, seed);
            common::assert_littles_law(&out.report, &spans(&out));
        }
    }
}

#[test]
fn prop_tokens_are_conserved() {
    // Sum of decoded tokens == sum of requested output lengths, from
    // three independent ledgers: the simulator's token counter, the
    // per-completion records, and the request trace itself.
    let mut rng = Rng::seed(7);
    for _ in 0..3 {
        let seed = rng.next_u64();
        let trace = DecodeWorkload::poisson(15.0, 600, seed).generate();
        let want: u64 = trace.iter().map(|r| r.output_len).sum();
        for policy in [
            DecodePolicy::Fifo(BatchPolicy::new(16, 0.010)),
            DecodePolicy::Continuous(ContinuousBatchPolicy::new(16)),
        ] {
            let (mut pf, mut dm) = models(Precision::Mixed);
            let out = DecodeSimulator::new(policy, 2.0).run("tok", &trace, &mut pf, &mut dm);
            assert_eq!(out.tokens, want, "{}", policy.label());
            let decoded: u64 = out.completions.iter().map(|c| c.decoded_tokens).sum();
            assert_eq!(decoded, want, "{}", policy.label());
            assert_eq!(out.completions.len(), 600);
        }
    }
}

#[test]
fn decode_at_cache_zero_prices_as_the_seq1_forward_slice() {
    // The tentpole identity: with an empty KV-cache, a decode step IS a
    // seq-1 forward pass — same ops, same flops, same bytes, and the
    // same roofline seconds through a real pricer, at several batches
    // and precisions.
    for prec in [Precision::Fp32, Precision::Mixed] {
        let pricer = Cached::new(RooflinePricer::new(DeviceSpec::mi100(), prec));
        for batch in [1u64, 4, 16] {
            let run = inference_run(ModelConfig::bert_large(), batch, 1, prec);
            let fwd = forward_graph(&run, ServeHead::Squad);
            let dec = decode_graph(&run, ServeHead::Squad, 0);
            assert_eq!(fwd.ops.len(), dec.ops.len());
            assert_eq!(fwd.total_flops(), dec.total_flops());
            let bytes = |g: &bertprof::model::IterationGraph| {
                g.ops.iter().map(|o| o.total_bytes()).sum::<u64>()
            };
            assert_eq!(bytes(&fwd), bytes(&dec));
            assert_eq!(
                pricer.iteration_seconds(&fwd),
                pricer.iteration_seconds(&dec),
                "B{batch} {prec:?}"
            );
        }
    }
}

#[test]
fn prop_kv_cache_bytes_grow_exactly_linearly() {
    // Capacity side: perf::memory's accounting is slope * kv_len.
    let run = inference_run(ModelConfig::bert_large(), 8, 1, Precision::Mixed);
    let slope = memory::kv_cache_bytes(&run, 1);
    assert!(slope > 0);
    for kv in [0u64, 1, 2, 17, 128, 511] {
        assert_eq!(memory::kv_cache_bytes(&run, kv), slope * kv);
    }
    // Traffic side: each +1 cache token adds the same byte count to the
    // decode graph (second difference exactly zero, in exact integers),
    // and the per-token slope covers at least the K+V reads themselves
    // (2 · n_layers · batch · d_model · elem_bytes).
    let total = |kv: u64| {
        decode_graph(&run, ServeHead::Squad, kv)
            .ops
            .iter()
            .map(|o| o.total_bytes())
            .sum::<u64>()
    };
    let step = total(1) - total(0);
    for kv in [1u64, 2, 63, 255] {
        assert_eq!(
            total(kv + 1) - total(kv),
            step,
            "byte growth not linear at cache {kv}"
        );
    }
    let cfg = &run.model;
    let act = run.precision.act_bytes();
    assert!(
        step >= 2 * cfg.n_layers * cfg.batch * cfg.d_model * act,
        "slope {step} misses the K+V read floor"
    );
}

#[test]
fn decode_model_prices_through_any_shared_pricer() {
    // The BatchCost seam: a DecodeModel under an explicitly shared
    // pricer returns bit-identical step times to a private one.
    let prec = Precision::Fp32;
    let pricer: Arc<dyn CostModel> =
        Arc::new(Cached::new(RooflinePricer::new(DeviceSpec::mi100(), prec)));
    let mut private = DecodeModel::new(ModelConfig::bert_large(), prec, DeviceSpec::mi100());
    let mut shared = DecodeModel::new(ModelConfig::bert_large(), prec, DeviceSpec::mi100())
        .with_pricer(Arc::clone(&pricer));
    for (b, kv) in [(1u64, 0u64), (8, 96), (32, 480)] {
        assert_eq!(private.step_seconds(b, kv), shared.step_seconds(b, kv));
    }
    // And the padded-cache grid matches the BatchCost view of it.
    assert_eq!(BatchCost::padded_seq(&private, 33), private.padded_cache(33));
}

#[test]
fn prop_same_seed_same_artifact() {
    // The serve_sim.rs artifact-identity check, decode edition, via the
    // shared helper: thread count must not change a byte; the seed must.
    common::assert_seeded_artifact_determinism(
        |seed, threads| {
            let mut cfg = DecodeSweepConfig::bert_large_default();
            cfg.requests = 400;
            cfg.slots = vec![8];
            cfg.seed = seed;
            decode_sweep_json(&cfg, &run_decode_sweep(&cfg, threads)).to_string()
        },
        42,
        7,
    );
}

#[test]
fn continuous_batching_beats_fifo_goodput_somewhere() {
    // The acceptance criterion on the golden grid: continuous batching
    // strictly dominates FIFO timeout+max-batch goodput at >= 1 swept
    // (device, SLO) point.
    let mut cfg = DecodeSweepConfig::bert_large_default();
    cfg.requests = 500;
    let reports = run_decode_sweep(&cfg, 4);
    let mut wins = 0;
    for pair in reports.chunks_exact(2) {
        assert_eq!(pair[0].policy, "fifo");
        assert_eq!(pair[1].policy, "continuous");
        if pair[1].sim.goodput > pair[0].sim.goodput {
            wins += 1;
        }
    }
    assert!(wins >= 1, "continuous batching never beat FIFO on the golden grid");
}

#[test]
fn fifo_pays_the_lock_step_padding_tax() {
    // Mechanism check behind the headline: on one identical trace at an
    // identical offered rate, the FIFO batch decodes more iterations
    // per served token (idle slots ride to the batch max), so its
    // request latency tail is no better than continuous batching's
    // median behavior under load.
    let (mut pf, mut dm) = models(Precision::Mixed);
    let trace = DecodeWorkload::poisson(18.0, 500, 13).generate();
    let fifo = DecodeSimulator::new(DecodePolicy::Fifo(BatchPolicy::new(16, 0.010)), 2.0)
        .run("fifo", &trace, &mut pf, &mut dm);
    let cont =
        DecodeSimulator::new(DecodePolicy::Continuous(ContinuousBatchPolicy::new(16)), 2.0)
            .run("cont", &trace, &mut pf, &mut dm);
    // Same tokens served...
    assert_eq!(fifo.tokens, cont.tokens);
    // ...but continuous needs no lock-step padding: its mean decoded
    // tokens per iteration is at least FIFO's.
    assert!(
        cont.report.mean_batch >= fifo.report.mean_batch,
        "continuous {} < fifo {}",
        cont.report.mean_batch,
        fifo.report.mean_batch
    );
}
