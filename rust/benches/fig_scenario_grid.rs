//! The scenario-grid bench: how much does the shared `perf::CostCache`
//! table buy on a realistic experiment grid?
//!
//! The grid is {batch x precision x device} of full BERT-Large
//! iteration timelines — the shape of the registry's fig04/fig09-style
//! scenarios. The uncached case re-prices every op per cell; the cached
//! case decorates each cell's `RooflinePricer` with `Cached` over one
//! shared table (exactly what the scenario engine and
//! `serve::run_sweep` do), so the batch-independent LAMB ops and every
//! repeated shape are priced once. The measured speedup and hit rate
//! are recorded to `BENCH_scenario_grid.json` — the first
//! `BENCH_*.json` data point — and the bench asserts the cached grid
//! totals are bit-identical to the uncached ones.

use std::sync::Arc;

use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::perf::device::DeviceSpec;
use bertprof::perf::{Cached, CostCache, RooflinePricer};
use bertprof::profiler::Timeline;
use bertprof::scenario::exec;
use bertprof::util::bench::{black_box, Bench};
use bertprof::util::Json;

fn grid() -> Vec<(RunConfig, DeviceSpec)> {
    let mut cells = Vec::new();
    for dev in [DeviceSpec::mi100(), DeviceSpec::v100(), DeviceSpec::a100()] {
        for prec in [Precision::Fp32, Precision::Mixed] {
            for b in [1u64, 2, 4, 8, 16, 32] {
                let run = RunConfig::new(
                    ModelConfig::bert_large().with_batch(b),
                    Phase::Phase1,
                    prec,
                );
                cells.push((run, dev.clone()));
            }
        }
    }
    cells
}

fn cell_pricer(
    run: &RunConfig,
    dev: &DeviceSpec,
    table: &Arc<CostCache>,
) -> Cached<RooflinePricer> {
    Cached::with_table(
        RooflinePricer::new(dev.clone(), run.precision),
        Arc::clone(table),
    )
}

fn main() {
    let cells = grid();
    println!(
        "## fig_scenario_grid — {} grid cells (3 devices x 2 precisions x 6 batches)",
        cells.len()
    );

    // Correctness first: the cache changes no modeled time.
    let cost = Arc::new(CostCache::new());
    for (run, dev) in &cells {
        let plain = Timeline::modeled(run, dev).total_seconds();
        let cached = Timeline::modeled_with(run, &cell_pricer(run, dev, &cost)).total_seconds();
        assert_eq!(plain, cached, "cache must be pure memoization");
    }
    let warm_rate = cost.hit_rate();
    println!(
        "cost-cache: {} shapes, {:.1}% hit rate over one grid pass",
        cost.len(),
        warm_rate * 100.0
    );

    let mut b = Bench::new("fig_scenario_grid");
    let uncached = b
        .run("grid uncached (fresh roofline per cell)", || {
            for (run, dev) in &cells {
                black_box(Timeline::modeled(run, dev));
            }
        })
        .median;
    let cached = b
        .run("grid cached (one CostCache table across cells)", || {
            let cost = Arc::new(CostCache::new());
            for (run, dev) in &cells {
                black_box(Timeline::modeled_with(run, &cell_pricer(run, dev, &cost)));
            }
        })
        .median;
    let warm = b
        .run("grid warm-cached (grid-lifetime CostCache table)", || {
            for (run, dev) in &cells {
                black_box(Timeline::modeled_with(run, &cell_pricer(run, dev, &cost)));
            }
        })
        .median;
    b.run("grid via exec::run_grid (parallel, shared cache)", || {
        let cost = Arc::new(CostCache::new());
        black_box(exec::run_grid(&cells, 8, |(run, dev)| {
            Timeline::modeled_with(run, &cell_pricer(run, dev, &cost)).total_seconds()
        }));
    });
    b.finish();

    let speedup = uncached.as_secs_f64() / cached.as_secs_f64();
    let warm_speedup = uncached.as_secs_f64() / warm.as_secs_f64();
    println!(
        "cached-vs-uncached speedup: {speedup:.2}x cold, {warm_speedup:.2}x warm"
    );

    let out = Json::obj(vec![
        ("bench", Json::str("fig_scenario_grid")),
        ("grid_cells", Json::num(cells.len() as f64)),
        ("uncached_median_us", Json::num(uncached.as_secs_f64() * 1e6)),
        ("cached_median_us", Json::num(cached.as_secs_f64() * 1e6)),
        ("warm_cached_median_us", Json::num(warm.as_secs_f64() * 1e6)),
        ("cached_speedup", Json::num(speedup)),
        ("warm_cached_speedup", Json::num(warm_speedup)),
        ("hit_rate", Json::num(warm_rate)),
    ]);
    let path = "BENCH_scenario_grid.json";
    std::fs::write(path, out.to_string()).expect("write bench artifact");
    println!("wrote {path}");
}
