//! Fig. 9 — impact of scaling the mini-batch size (B = 4..32) on the
//! runtime breakdown: LAMB's share shrinks as token count grows.
use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::perf::device::DeviceSpec;
use bertprof::profiler::{report, Timeline};
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let dev = DeviceSpec::mi100();
    let timelines: Vec<Timeline> = [4u64, 8, 16, 32]
        .iter()
        .map(|&bsz| Timeline::modeled(
            &RunConfig::new(ModelConfig::bert_large().with_batch(bsz),
                            Phase::Phase1, Precision::Fp32), &dev))
        .collect();
    println!("{}", report::stacked_table("Fig. 9 — mini-batch sweep", &timelines));

    let mut b = Bench::new("fig09");
    b.run("batch sweep (4 configs)", || {
        for bsz in [4u64, 8, 16, 32] {
            let r = RunConfig::new(ModelConfig::bert_large().with_batch(bsz),
                                   Phase::Phase1, Precision::Fp32);
            black_box(Timeline::modeled(&r, &dev));
        }
    });
    b.finish();
}
