//! The fleet bench: what does one multi-replica simulation cost per
//! routing policy, and what does the autoscaler's tick loop add?
//!
//! Three questions on the SSFleet grid (DESIGN.md):
//!
//! 1. **Routing cost** — one heterogeneous-pool run per policy
//!    (round-robin, least-loaded, power-of-two-choices) over the same
//!    diurnal trace, all batch prices pre-memoized.
//! 2. **Autoscaler overhead** — the same pool and trace with the
//!    queue-depth autoscaler ticking vs static.
//! 3. **Headline sanity** — the bench asserts request conservation and
//!    per-policy determinism before timing anything.
//!
//! Results land in `BENCH_fleet.json` (wired into `make artifacts`).

use bertprof::config::{ModelConfig, Precision};
use bertprof::perf::device::DeviceSpec;
use bertprof::serve::{
    ArrivalProcess, AutoscalerConfig, BatchPolicy, Fleet, LatencyModel, Routing, ROUTE_SEED_SALT,
};
use bertprof::util::bench::{black_box, Bench};
use bertprof::util::Json;

const REQUESTS: u64 = 2_000;
const SEED: u64 = 42;

fn pool() -> Vec<(String, LatencyModel)> {
    let prec = Precision::Mixed;
    [DeviceSpec::mi100(), DeviceSpec::a100(), DeviceSpec::v100()]
        .into_iter()
        .flat_map(|d| {
            (0..2).map(move |_| {
                (
                    d.name.clone(),
                    LatencyModel::new(ModelConfig::bert_large(), prec, d.clone()),
                )
            })
        })
        .collect()
}

fn main() {
    let arrivals = ArrivalProcess::Diurnal { base: 350.0, amplitude: 0.6, period: 3.0 };
    let trace = arrivals.generate(REQUESTS, SEED, 16, 128);
    let fleet = Fleet::new(BatchPolicy::new(8, 0.010), 0.100);
    let auto = AutoscalerConfig {
        enabled: true,
        min_replicas: 3,
        max_replicas: 6,
        up_threshold: 12.0,
        down_threshold: 4.0,
        tick: 0.1,
        cooldown_ticks: 2,
        warmup: 0.2,
    };
    println!(
        "## fig_fleet — {} requests over a 6-replica heterogeneous pool, per routing policy",
        REQUESTS
    );

    // Correctness first: conservation and per-policy determinism.
    for routing in [Routing::RoundRobin, Routing::LeastLoaded, Routing::PowerOfTwo] {
        let run = |_: usize| {
            let mut p = routing.build();
            fleet
                .run("sanity", &trace, pool(), p.as_mut(), SEED ^ ROUTE_SEED_SALT)
                .report
        };
        let (a, b) = (run(0), run(1));
        assert_eq!(a.admitted, REQUESTS, "{} lost requests", routing.label());
        assert_eq!(a.rejected, 0);
        assert_eq!(a.sim.p99, b.sim.p99, "{} is nondeterministic", routing.label());
    }

    let mut b = Bench::new("fig_fleet");
    let mut medians: Vec<(String, std::time::Duration)> = Vec::new();
    for routing in [Routing::RoundRobin, Routing::LeastLoaded, Routing::PowerOfTwo] {
        let label = format!("{} static fleet run ({REQUESTS} req)", routing.label());
        let t = b
            .run(&label, || {
                let mut p = routing.build();
                let out = fleet.run("bench", &trace, pool(), p.as_mut(), SEED ^ ROUTE_SEED_SALT);
                black_box(out.report.sim.goodput);
            })
            .median;
        medians.push((routing.label().to_string(), t));
    }
    let auto_t = b
        .run(&format!("p2c autoscaled fleet run ({REQUESTS} req)"), || {
            let mut p = Routing::PowerOfTwo.build();
            let out = fleet
                .clone()
                .with_autoscaler(auto)
                .run("bench", &trace, pool(), p.as_mut(), SEED ^ ROUTE_SEED_SALT);
            black_box(out.report.sim.goodput);
        })
        .median;
    b.finish();

    let us = |d: std::time::Duration| d.as_secs_f64() * 1e6;
    let p2c_t = medians.last().expect("three policies").1;
    println!(
        "autoscaler tick loop costs {:.2}x the static p2c run",
        us(auto_t) / us(p2c_t).max(1e-9)
    );

    let mut pairs = vec![
        ("bench", Json::str("fig_fleet")),
        ("sim_requests", Json::num(REQUESTS as f64)),
        ("pool_replicas", Json::num(6.0)),
        ("autoscaled_median_us", Json::num(us(auto_t))),
        (
            "autoscaler_overhead",
            Json::num(us(auto_t) / us(p2c_t).max(1e-9)),
        ),
    ];
    for (name, t) in &medians {
        pairs.push(match name.as_str() {
            "rr" => ("rr_median_us", Json::num(us(*t))),
            "ll" => ("ll_median_us", Json::num(us(*t))),
            _ => ("p2c_median_us", Json::num(us(*t))),
        });
    }
    let out = Json::obj(pairs);
    let path = "BENCH_fleet.json";
    std::fs::write(path, out.to_string()).expect("write bench artifact");
    println!("wrote {path}");
}
