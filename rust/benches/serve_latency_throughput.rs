//! SSServe — serving latency/throughput study: dynamic batching vs
//! no-batching, FP32 vs Mixed, on the MI100 preset, plus timings of the
//! latency-model and simulator hot paths.
use bertprof::config::{ModelConfig, Precision};
use bertprof::perf::device::DeviceSpec;
use bertprof::serve::{
    run_sweep, BatchCost, BatchPolicy, LatencyModel, Simulator, SweepConfig, Workload,
};
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let mut cfg = SweepConfig::bert_large_default();
    cfg.requests = 4_000;
    println!(
        "## SSServe — dynamic batching (modeled, {} req/scenario, load {:.0}%, SLO {:.0} ms)",
        cfg.requests,
        cfg.load * 100.0,
        cfg.slo * 1e3
    );
    println!(
        "{:<22}{:>9}{:>7}{:>7}{:>9}{:>9}{:>7}",
        "config", "thr/s", "util", "bsz", "p50(ms)", "p99(ms)", "SLO%"
    );
    for r in run_sweep(&cfg, 4) {
        println!(
            "{:<22}{:>9.1}{:>7.2}{:>7.2}{:>9.1}{:>9.1}{:>6.1}%",
            r.label,
            r.throughput,
            r.utilization,
            r.mean_batch,
            r.p50 * 1e3,
            r.p99 * 1e3,
            r.slo_attainment * 100.0
        );
    }

    let mut b = Bench::new("serve");
    let model = ModelConfig::bert_large();
    b.run("latency model, cold shape grid (B1..32, n128)", || {
        let mut lm = LatencyModel::new(model, Precision::Fp32, DeviceSpec::mi100());
        for batch in 1..=32 {
            black_box(lm.batch_seconds(batch, 128));
        }
    });
    let mut warm = LatencyModel::new(model, Precision::Fp32, DeviceSpec::mi100());
    warm.batch_seconds(8, 128);
    b.run("latency model, warm lookup", || {
        black_box(warm.batch_seconds(8, 128));
    });
    let mut lm = LatencyModel::new(model, Precision::Mixed, DeviceSpec::mi100());
    let rate = 0.65 * lm.saturation_rate(8, 128);
    let trace = Workload::poisson(rate, 4_000, 42).generate();
    b.run("simulate 4k requests (B8/10ms)", || {
        black_box(
            Simulator::new(BatchPolicy::new(8, 0.010), 0.100).run("bench", &trace, &mut lm),
        );
    });
    b.finish();
}
