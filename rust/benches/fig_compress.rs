//! SSCompress — the compression what-if grid: quantized/pruned BERT
//! variants against the 100 ms serving SLO. Prints a reduced grid and
//! benchmarks the compressed-latency pipeline (prune transform + quant
//! costing + simulation).
use bertprof::compress::{
    default_variants, run_scenario, CompressSweepConfig, CompressedLatencyModel,
};
use bertprof::perf::device::DeviceSpec;
use bertprof::serve::BatchCost;
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let mut cfg = CompressSweepConfig::bert_large_default();
    cfg.devices = vec![DeviceSpec::mi100()];
    cfg.requests = 1_000;
    println!(
        "## SSCompress — SLO what-if (reduced grid, {} req/scenario, SLO {:.0} ms)",
        cfg.requests,
        cfg.slo * 1e3
    );
    println!(
        "{:<26}{:>9}{:>9}{:>9}{:>7}",
        "config", "thr/s", "p50(ms)", "p99(ms)", "SLO%"
    );
    let scenarios = cfg.scenarios();
    for s in &scenarios {
        let r = run_scenario(&cfg, s);
        println!(
            "{:<26}{:>9.1}{:>9.1}{:>9.1}{:>6.1}%",
            r.label,
            r.throughput,
            r.p50 * 1e3,
            r.p99 * 1e3,
            r.slo_attainment * 100.0
        );
    }

    let mut b = Bench::new("fig_compress");
    let variants = default_variants(&cfg.model);
    let pruned = variants.last().expect("pruned-w8a8").clone();
    b.run("prune+quant batch cost (cold cache)", || {
        let mut lm = CompressedLatencyModel::new(cfg.model, &pruned, DeviceSpec::mi100());
        black_box(lm.batch_seconds(32, 128));
    });
    b.run("one scenario end-to-end (1k requests)", || {
        black_box(run_scenario(&cfg, &scenarios[scenarios.len() - 1]));
    });
    b.finish();
}
