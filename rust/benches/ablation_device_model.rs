//! Ablation: which parts of the calibrated device model matter?
//!
//! DESIGN.md calls out three roofline design choices:
//!   (a) FP32 GEMMs on vector units + achieved-efficiency calibration,
//!   (b) latency-bound EW bandwidth (ew_bw) vs streaming bandwidth,
//!   (c) separate optimizer-stream bandwidth (opt_bw).
//! This bench re-runs Fig. 4's Ph1-B32-FP32 row with each choice ablated
//! to a naive peak-everything model and reports how the headline shares
//! move — demonstrating that the paper's breakdown *cannot* be
//! reproduced from theoretical peaks alone.

use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::perf::device::DeviceSpec;
use bertprof::profiler::Timeline;
use bertprof::util::bench::{black_box, Bench};

fn shares(dev: &DeviceSpec) -> (f64, f64, f64) {
    let run = RunConfig::new(ModelConfig::bert_large(), Phase::Phase1, Precision::Fp32);
    let t = Timeline::modeled(&run, dev);
    let lf = t.layer_fractions();
    let cats = t.category_fractions();
    let gemm: f64 = cats.iter().filter(|(k, _)| k.contains("GEMM")).map(|(_, v)| v).sum();
    (
        lf.get("Transformer").copied().unwrap_or(0.0),
        lf.get("LAMB").copied().unwrap_or(0.0),
        gemm,
    )
}

fn main() {
    let calibrated = DeviceSpec::mi100();

    let mut no_gemm_calib = calibrated.clone();
    no_gemm_calib.name = "-gemm-calib".into();
    no_gemm_calib.fp32_matrix_flops = 46.1e12; // matrix-core peak
    no_gemm_calib.matrix_eff_fp32 = 1.0;
    no_gemm_calib.matrix_eff_fp16 = 1.0;

    let mut no_ew_calib = calibrated.clone();
    no_ew_calib.name = "-ew-latency".into();
    no_ew_calib.ew_bw_efficiency = no_ew_calib.bw_efficiency;

    let mut no_opt_split = calibrated.clone();
    no_opt_split.name = "-opt-split".into();
    no_opt_split.opt_bw_efficiency = no_opt_split.ew_bw_efficiency;

    let mut naive = no_gemm_calib.clone();
    naive.name = "naive-peaks".into();
    naive.ew_bw_efficiency = naive.bw_efficiency;
    naive.opt_bw_efficiency = naive.bw_efficiency;

    println!("## Ablation — Fig. 4 Ph1-B32-FP32 shares under ablated device models");
    println!("paper targets: GEMM ~60%, LAMB 7-20%, non-GEMM 30-40%\n");
    println!("{:<14}{:>12}{:>10}{:>10}", "model", "xformer%", "lamb%", "gemm%");
    for dev in [&calibrated, &no_gemm_calib, &no_ew_calib, &no_opt_split, &naive] {
        let (tf, lamb, gemm) = shares(dev);
        println!("{:<14}{:>11.1}%{:>9.1}%{:>9.1}%",
                 dev.name, 100.0 * tf, 100.0 * lamb, 100.0 * gemm);
    }
    println!("\n(naive peaks push GEMMs far below the paper's share and distort");
    println!(" LAMB; each calibration term moves the breakdown toward rocProf.)");

    let mut b = Bench::new("ablation");
    b.run("5 device variants", || {
        for dev in [&calibrated, &no_gemm_calib, &no_ew_calib, &no_opt_split, &naive] {
            black_box(shares(dev));
        }
    });
    b.finish();
}
