//! Fig. 13 — kernel-fusion impact (LayerNorm, Adam): kernel count,
//! execution time, and memory traffic, fused normalized to unfused.
//! Prints the modeled ratios and, when artifacts exist, the *measured*
//! ratios from executing the fused/unfused HLO sequences on CPU PJRT.
use std::path::PathBuf;

use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::coordinator::MeasureRunner;
use bertprof::fusion::kernel_fusion::FusionStudy;
use bertprof::perf::device::DeviceSpec;
use bertprof::runtime::Runtime;
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let dev = DeviceSpec::mi100();
    let run = RunConfig::new(ModelConfig::bert_large(), Phase::Phase1, Precision::Fp32);
    println!("## Fig. 13 — kernel fusion (modeled; fused/unfused ratios)");
    println!("{:<14}{:>12}{:>12}{:>12}", "study", "kernels", "time", "traffic");
    for s in [FusionStudy::layernorm(&run, &dev), FusionStudy::adam(&run, &dev)] {
        println!("{:<14}{:>12.3}{:>12.3}{:>12.3}",
                 s.name, s.kernel_ratio, s.time_ratio, s.traffic_ratio);
    }

    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let mut rt = Runtime::load(&dir).unwrap();
        let mut mr = MeasureRunner::new(&mut rt, 5);
        println!("\n## Fig. 13 — measured (CPU PJRT; fused/unfused ratios)");
        println!("{:<18}{:>12}{:>12}", "study", "kernels", "time");
        for (label, unf, fus) in [
            ("LayerNorm", "layernorm_unfused", "layernorm_fused"),
            ("DR+Res+LN", "drln_unfused", "drln_fused"),
            ("Adam", "adam_unfused", "adam_fused"),
        ] {
            let (k, t) = mr.fusion_ratio(unf, fus).unwrap();
            println!("{:<18}{:>12.3}{:>12.3}", label, k, t);
        }
    }

    let mut b = Bench::new("fig13");
    b.run("modeled fusion studies", || {
        black_box(FusionStudy::layernorm(&run, &dev));
        black_box(FusionStudy::adam(&run, &dev));
    });
    b.finish();
}
