//! The gridscale bench: does the scaled engine actually scale?
//!
//! Two head-to-heads on the SSGridScale harness workload (DESIGN.md),
//! each at 1/2/4/8 worker threads over the same synthetic grid:
//!
//! 1. **Sharded vs single-lock cost cache** — the same chunked
//!    executor pricing the grid through `CostCache::for_threads(t)`
//!    (striped) versus `CostCache::with_shards(1)` (the pre-PR
//!    one-big-mutex layout).
//! 2. **Chunked vs cell-stride claiming** — the same sharded cache
//!    driven by `exec::run_grid` (contiguous chunk claims) versus
//!    `exec::run_grid_cell_stride` (the pre-PR one-cell-per-cursor-bump
//!    loop with per-slot locks).
//!
//! Correctness asserts (thread-count determinism, sharded == single-
//! lock results, chunked == stride results) run before any timing.
//! Results land in `BENCH_gridscale.json` (wired into `make
//! artifacts`); when cargo is unavailable the committed file is the
//! mirror's estimate and says so via `"estimated": true` — this bench
//! overwrites it with measured numbers.

use std::sync::Arc;

use bertprof::model::{GraphIntern, GraphKey, IterationGraph};
use bertprof::perf::{Cached, CostCache, CostModel, RooflinePricer};
use bertprof::scenario::exec;
use bertprof::scenario::gridscale::{grid_cells, run_gridscale, GridCell, GridScaleConfig};
use bertprof::serve::graph::inference_run;
use bertprof::util::bench::{black_box, Bench};
use bertprof::util::Json;

/// Price the whole grid through a caller-chosen table and executor;
/// returns the grid-order throughput checksum.
fn price_grid(cfg: &GridScaleConfig, threads: usize, table: &Arc<CostCache>, chunked: bool) -> f64 {
    let grid = grid_cells(cfg);
    let intern = Arc::new(GraphIntern::new());
    let cell_fn = |cell: &GridCell| {
        let run = inference_run(cfg.model, cell.batch, cfg.seq_len, cell.precision);
        let g = intern
            .get_or_build(GraphKey::base(&run, 0), || IterationGraph::build_inference(&run));
        let pricer = Cached::with_table(
            RooflinePricer::new(cfg.devices[cell.device].clone(), cell.precision),
            Arc::clone(table),
        );
        (cell.replicas * cell.batch) as f64 / pricer.iteration_seconds(&g)
    };
    let out = if chunked {
        exec::run_grid(&grid, threads, cell_fn)
    } else {
        exec::run_grid_cell_stride(&grid, threads, cell_fn)
    };
    out.iter().sum()
}

fn main() {
    let cfg = GridScaleConfig::default_with_cells(20_000);
    println!(
        "## fig_gridscale — {} cells ({} combos x {} replica planes)",
        cfg.total_cells(),
        cfg.base_cells(),
        cfg.replicas()
    );

    // Correctness first: the engine is deterministic across thread
    // counts and across every cache/executor variant under test.
    let base = run_gridscale(&cfg, 1);
    let multi = run_gridscale(&cfg, 4);
    assert_eq!(base.checksum, multi.checksum, "engine is nondeterministic");
    assert_eq!(base.cache.hits, multi.cache.hits, "cache split drifted");
    assert_eq!(base.intern, multi.intern, "intern split drifted");
    let single_lock = price_grid(&cfg, 4, &Arc::new(CostCache::with_shards(1)), true);
    assert_eq!(single_lock, base.checksum, "single-lock table diverged");
    let strided = price_grid(&cfg, 4, &Arc::new(CostCache::for_threads(4)), false);
    assert_eq!(strided, base.checksum, "cell-stride executor diverged");

    let threads = [1usize, 2, 4, 8];
    let mut bench = Bench::new("fig_gridscale");
    let sec = |d: std::time::Duration| d.as_secs_f64();
    let mut cache_speedup = Vec::new();
    let mut exec_speedup = Vec::new();
    let mut sharded_secs = Vec::new();
    for &t in &threads {
        let sharded = sec(bench
            .run(&format!("sharded cache, chunked exec, {t}t"), || {
                let table = Arc::new(CostCache::for_threads(t));
                black_box(price_grid(&cfg, t, &table, true));
            })
            .median);
        let one_lock = sec(bench
            .run(&format!("single-lock cache, chunked exec, {t}t"), || {
                let table = Arc::new(CostCache::with_shards(1));
                black_box(price_grid(&cfg, t, &table, true));
            })
            .median);
        let stride = sec(bench
            .run(&format!("sharded cache, cell-stride exec, {t}t"), || {
                let table = Arc::new(CostCache::for_threads(t));
                black_box(price_grid(&cfg, t, &table, false));
            })
            .median);
        cache_speedup.push(one_lock / sharded.max(1e-12));
        exec_speedup.push(stride / sharded.max(1e-12));
        sharded_secs.push(sharded);
    }
    bench.finish();

    let cells = cfg.total_cells() as f64;
    for (i, &t) in threads.iter().enumerate() {
        println!(
            "{t}t: sharded-vs-single-lock {:.2}x, chunked-vs-stride {:.2}x, {:.0} cells/s",
            cache_speedup[i],
            exec_speedup[i],
            cells / sharded_secs[i].max(1e-12)
        );
    }

    let per_thread = |v: &[f64]| {
        Json::obj(vec![
            ("t1", Json::num(v[0])),
            ("t2", Json::num(v[1])),
            ("t4", Json::num(v[2])),
            ("t8", Json::num(v[3])),
        ])
    };
    let out = Json::obj(vec![
        ("bench", Json::str("fig_gridscale")),
        ("estimated", Json::Bool(false)),
        ("cells", Json::num(cells)),
        ("base_cells", Json::num(cfg.base_cells() as f64)),
        ("replicas", Json::num(cfg.replicas() as f64)),
        ("sharded_vs_single_lock", per_thread(&cache_speedup)),
        ("chunked_vs_cell_stride", per_thread(&exec_speedup)),
        (
            "cells_per_sec",
            per_thread(&sharded_secs.iter().map(|s| cells / s.max(1e-12)).collect::<Vec<f64>>()),
        ),
    ]);
    let path = "BENCH_gridscale.json";
    std::fs::write(path, out.to_string()).expect("write bench artifact");
    println!("wrote {path}");
}
