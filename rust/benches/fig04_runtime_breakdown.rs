//! Fig. 4 — runtime breakdown of BERT pre-training across phases,
//! mini-batch sizes, and precisions. Prints the five Phi-Bj-FPk rows and
//! benchmarks the analytic pipeline (graph build + roofline eval).
use bertprof::config::RunConfig;
use bertprof::model::IterationGraph;
use bertprof::perf::device::DeviceSpec;
use bertprof::profiler::{report, Timeline};
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let dev = DeviceSpec::mi100();
    let timelines: Vec<Timeline> = RunConfig::figure4_set()
        .iter()
        .map(|r| Timeline::modeled(r, &dev))
        .collect();
    println!("{}", report::stacked_table(
        "Fig. 4 — runtime breakdown (modeled, MI100)", &timelines));

    let mut b = Bench::new("fig04");
    let run = RunConfig::figure4_set()[0];
    b.run("IterationGraph::build (BERT Large)", || {
        black_box(IterationGraph::build(&run));
    });
    b.run("Timeline::modeled (graph+roofline)", || {
        black_box(Timeline::modeled(&run, &dev));
    });
    b.run("full figure (5 configs)", || {
        for r in RunConfig::figure4_set() {
            black_box(Timeline::modeled(&r, &dev));
        }
    });
    b.finish();
}
