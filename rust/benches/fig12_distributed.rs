//! Fig. 12 — multi-device iteration breakdown: data parallel with and
//! without overlap, Megatron-style 2-way / 8-way model parallel, and the
//! 128-GPU hybrid.
use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::dist::{DataParallelModel, HybridModel, LinkSpec, ModelParallelModel};
use bertprof::perf::device::DeviceSpec;
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let dev = DeviceSpec::mi100();
    let b16 = RunConfig::new(ModelConfig::bert_large().with_batch(16),
                             Phase::Phase1, Precision::Fp32);
    let b64 = RunConfig::new(ModelConfig::bert_large().with_batch(64),
                             Phase::Phase1, Precision::Fp32);
    let link = LinkSpec::pcie4x16();
    println!("## Fig. 12 — multi-device training (modeled, PCIe 4.0)");
    println!("{:<26}{:>12}{:>10}{:>10}{:>10}", "config", "total(ms)", "xfmr%", "lamb%", "comm%");
    for bd in [
        DataParallelModel::new(1, link.clone(), true).breakdown(&b16, &dev),
        DataParallelModel::new(64, link.clone(), true).breakdown(&b16, &dev),
        DataParallelModel::new(64, link.clone(), false).breakdown(&b16, &dev),
        ModelParallelModel::new(2, link.clone()).breakdown(&b16, &dev),
        ModelParallelModel::new(8, link.clone()).breakdown(&b64, &dev),
        HybridModel::megatron_128().breakdown(&b16, &dev),
    ] {
        println!("{:<26}{:>12.1}{:>9.1}%{:>9.1}%{:>9.1}%",
                 bd.label, bd.total() * 1e3,
                 100.0 * bd.transformer / bd.total(),
                 100.0 * bd.lamb_fraction(),
                 100.0 * bd.comm_fraction());
    }

    let mut b = Bench::new("fig12");
    b.run("all 6 distributed breakdowns", || {
        black_box(DataParallelModel::new(64, link.clone(), true).breakdown(&b16, &dev));
        black_box(DataParallelModel::new(64, link.clone(), false).breakdown(&b16, &dev));
        black_box(ModelParallelModel::new(2, link.clone()).breakdown(&b16, &dev));
        black_box(ModelParallelModel::new(8, link.clone()).breakdown(&b64, &dev));
        black_box(HybridModel::megatron_128().breakdown(&b16, &dev));
    });
    b.finish();
}
