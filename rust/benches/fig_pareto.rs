//! The pareto bench: what does one successive-halving rung cost, and
//! how much does the shared op-price table actually save?
//!
//! Three questions on the SSPareto search (DESIGN.md):
//!
//! 1. **Cold evaluation** — scoring a candidate against a fresh
//!    `CostCache` (every op shape priced from the roofline).
//! 2. **Warm evaluation** — the same candidate against the table a
//!    prior rung already filled (every lookup a hit) — the reuse that
//!    makes the 576-candidate default budget cheap.
//! 3. **Whole search** — the full halving loop on a 16-candidate
//!    space, the unit CI runs repeatedly.
//!
//! Correctness asserts (determinism, dedup rate) run before timing.
//! Results land in `BENCH_pareto.json` (wired into `make artifacts`).

use std::sync::Arc;

use bertprof::compress::{CompressPrecision, PruneSpec};
use bertprof::config::ModelConfig;
use bertprof::perf::device::DeviceSpec;
use bertprof::perf::CostCache;
use bertprof::scenario::pareto::{
    evaluate_candidate, run_search, Candidate, ParetoSearchConfig,
};
use bertprof::util::bench::{black_box, Bench};
use bertprof::util::Json;

fn bench_cfg() -> ParetoSearchConfig {
    let model = ModelConfig::bert_large();
    ParetoSearchConfig {
        model,
        devices: vec![DeviceSpec::mi100()],
        prunes: vec![
            PruneSpec::dense(&model),
            PruneSpec::dense(&model)
                .keep_heads(model.n_heads / 2)
                .keep_ff(model.d_ff / 2),
        ],
        precisions: vec![CompressPrecision::Mixed, CompressPrecision::Int8Full],
        max_batches: vec![8, 32],
        replicas: vec![1, 2],
        rungs: 3,
        requests: 400,
        seed: 42,
        slo: 0.100,
        max_wait: 0.010,
        demand: 2.0,
        seq_max: 128,
    }
}

fn main() {
    let cfg = bench_cfg();
    let cand = Candidate {
        device: DeviceSpec::mi100(),
        prune: PruneSpec::dense(&cfg.model),
        precision: CompressPrecision::Int8Full,
        max_batch: 8,
        replicas: 2,
    };
    println!(
        "## fig_pareto — {}-candidate space, {} rungs, {} final-rung requests",
        cfg.candidates().len(),
        cfg.rungs,
        cfg.requests
    );

    // Correctness first: the search is deterministic and the shared
    // table dedups the bulk of its lookups.
    let (a, ta) = run_search(&cfg, 1);
    let (b, _) = run_search(&cfg, 4);
    assert_eq!(a.frontier, b.frontier, "search is nondeterministic");
    assert_eq!(a.searched, b.searched);
    assert!(ta.dedup_rate() > 0.5, "dedup {:.2}", ta.dedup_rate());

    let demand = {
        let t = Arc::new(CostCache::new());
        cfg.demand_rps(&t)
    };
    let warm_table = Arc::new(CostCache::new());
    let warmed = evaluate_candidate(&cfg, &cand, cfg.requests, demand, &warm_table);

    let mut bench = Bench::new("fig_pareto");
    let cold_t = bench
        .run("candidate eval, cold table (400 req, x2)", || {
            let table = Arc::new(CostCache::new());
            let p = evaluate_candidate(&cfg, &cand, cfg.requests, demand, &table);
            black_box(p.p99);
        })
        .median;
    let warm_t = bench
        .run("candidate eval, warm table (400 req, x2)", || {
            let p = evaluate_candidate(&cfg, &cand, cfg.requests, demand, &warm_table);
            assert_eq!(p.p99, warmed.p99, "warm eval drifted");
            black_box(p.p99);
        })
        .median;
    let search_t = bench
        .run("full halving search (16 candidates, 3 rungs)", || {
            let (o, _) = run_search(&cfg, 2);
            black_box(o.searched);
        })
        .median;
    bench.finish();

    let us = |d: std::time::Duration| d.as_secs_f64() * 1e6;
    println!(
        "shared table makes a re-evaluation {:.2}x cheaper than a cold one",
        us(cold_t) / us(warm_t).max(1e-9)
    );

    let out = Json::obj(vec![
        ("bench", Json::str("fig_pareto")),
        ("space_candidates", Json::num(cfg.candidates().len() as f64)),
        ("searched_points", Json::num(a.searched as f64)),
        ("dedup_rate", Json::num(ta.dedup_rate())),
        ("eval_cold_median_us", Json::num(us(cold_t))),
        ("eval_warm_median_us", Json::num(us(warm_t))),
        ("cache_speedup", Json::num(us(cold_t) / us(warm_t).max(1e-9))),
        ("search_median_us", Json::num(us(search_t))),
    ]);
    let path = "BENCH_pareto.json";
    std::fs::write(path, out.to_string()).expect("write bench artifact");
    println!("wrote {path}");
}
