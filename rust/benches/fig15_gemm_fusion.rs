//! Fig. 15 — fusing the three attention linear GEMMs (QKV) vs serial:
//! modeled speedups across token counts, plus the measured CPU-PJRT
//! ratio of the qkv_fused vs 3x single-GEMM artifact sequences.
use std::path::PathBuf;

use bertprof::config::Precision;
use bertprof::coordinator::MeasureRunner;
use bertprof::fusion::gemm_fusion;
use bertprof::perf::device::DeviceSpec;
use bertprof::runtime::Runtime;
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let dev = DeviceSpec::mi100();
    println!("## Fig. 15 — QKV GEMM fusion speedup (modeled, fused vs 3x serial)");
    println!("{:<22}{:>10}{:>10}{:>10}", "point", "fwd", "dgrad", "wgrad");
    for r in gemm_fusion::figure15_sweep(&dev, Precision::Fp32) {
        println!("{:<22}{:>9.2}x{:>9.2}x{:>9.2}x", r.label,
                 1.0 / r.fwd_ratio, 1.0 / r.bwd_dgrad_ratio, 1.0 / r.bwd_wgrad_ratio);
    }

    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let mut rt = Runtime::load(&dir).unwrap();
        let mut mr = MeasureRunner::new(&mut rt, 5);
        let (k, t) = mr.fusion_ratio("qkv_unfused", "qkv_fused").unwrap();
        println!("\nmeasured (CPU PJRT): kernels {k:.3}, time {t:.3} (fused/unfused)");
        println!("=> measured speedup {:.2}x", 1.0 / t);
    }

    let mut b = Bench::new("fig15");
    b.run("figure15 modeled sweep", || {
        black_box(gemm_fusion::figure15_sweep(&dev, Precision::Fp32));
    });
    b.finish();
}
