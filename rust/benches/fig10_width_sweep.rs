//! Fig. 10 — impact of scaling the transformer layer size (hidden dim
//! 512..2048, d_ff = 4*d): GEMM and LAMB shares grow quadratically.
use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::perf::device::DeviceSpec;
use bertprof::profiler::{report, Timeline};
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let dev = DeviceSpec::mi100();
    let timelines: Vec<Timeline> = [512u64, 768, 1024, 1536, 2048]
        .iter()
        .map(|&w| {
            let r = RunConfig::new(ModelConfig::bert_large().with_width(w),
                                   Phase::Phase1, Precision::Fp32);
            let mut t = Timeline::modeled(&r, &dev);
            t.label = format!("d_model={w}");
            t
        })
        .collect();
    println!("{}", report::stacked_table("Fig. 10 — hidden-dim sweep", &timelines));

    let mut b = Bench::new("fig10");
    b.run("width sweep (5 configs)", || {
        for w in [512u64, 768, 1024, 1536, 2048] {
            let r = RunConfig::new(ModelConfig::bert_large().with_width(w),
                                   Phase::Phase1, Precision::Fp32);
            black_box(Timeline::modeled(&r, &dev));
        }
    });
    b.finish();
}
