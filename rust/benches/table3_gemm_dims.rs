//! Table 3 — architecture-agnostic sizes of BERT GEMMs, instantiated for
//! BERT Large at Ph1/B=32 and verified against the symbolic forms.
use bertprof::config::ModelConfig;
use bertprof::model::gemm::table3;
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let cfg = ModelConfig::bert_large();
    println!("## Table 3 — BERT GEMM dims (B={}, n={}, d={}, h={}, d_ff={})",
             cfg.batch, cfg.seq_len, cfg.d_model, cfg.n_heads, cfg.d_ff);
    println!("{:<16}{:>22}{:>22}{:>22}", "op", "FWD", "BWD dgrad", "BWD wgrad");
    let fmt = |g: &bertprof::model::GemmDims| {
        if g.batch > 1 {
            format!("{}x{}x{},b{}", g.m, g.n, g.k, g.batch)
        } else {
            format!("{}x{}x{}", g.m, g.n, g.k)
        }
    };
    for row in table3(&cfg) {
        println!("{:<16}{:>22}{:>22}{:>22}",
                 row.kind.label(), fmt(&row.fwd), fmt(&row.bwd_dgrad), fmt(&row.bwd_wgrad));
    }

    let mut b = Bench::new("table3");
    b.run("table3 generation", || {
        black_box(table3(&cfg));
    });
    b.finish();
}
