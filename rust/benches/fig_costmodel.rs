//! The cost-model bench: what does the one-pricer API cost, and what
//! does its caching decorator buy, on the serving grid's graphs?
//!
//! Three questions, answered on the {batch x padded-seq x precision}
//! forward graphs the serve sweep prices (DESIGN.md SSCost):
//!
//! 1. **Trait dispatch** — `RooflinePricer` called statically vs through
//!    `&dyn CostModel` (the price of the pluggable seam; expected to be
//!    noise next to the roofline arithmetic).
//! 2. **Identity decorators** — an empty `CalibratedPricer` layered on
//!    the analytic backend (the cost of composing a no-op policy).
//! 3. **Caching** — `Cached` cold (fresh table) and warm (grid-lifetime
//!    table) vs bare pricing.
//!
//! Results land in `BENCH_costmodel.json` (the `fig_costmodel` bench
//! trajectory's first point, wired into `make artifacts`); the bench
//! asserts every variant prices the grid bit-identically first.

use std::sync::Arc;

use bertprof::config::{ModelConfig, Precision};
use bertprof::model::IterationGraph;
use bertprof::perf::device::DeviceSpec;
use bertprof::perf::{Cached, CalibratedPricer, CostCache, CostModel, RooflinePricer};
use bertprof::serve::{forward_graph, inference_run, ServeHead};
use bertprof::util::bench::{black_box, Bench};
use bertprof::util::Json;

/// The serve grid's padded forward shapes, as (graph, precision) cells.
fn grid() -> Vec<(IterationGraph, Precision)> {
    let mut cells = Vec::new();
    for prec in [Precision::Fp32, Precision::Mixed] {
        for batch in [1u64, 8, 32] {
            for seq in [32u64, 64, 128] {
                let run = inference_run(ModelConfig::bert_large(), batch, seq, prec);
                cells.push((forward_graph(&run, ServeHead::Squad), prec));
            }
        }
    }
    cells
}

fn main() {
    let cells = grid();
    let dev = DeviceSpec::mi100();
    let ops: usize = cells.iter().map(|(g, _)| g.ops.len()).sum();
    println!(
        "## fig_costmodel — {} serve-grid graphs ({} ops total) on {}",
        cells.len(),
        ops,
        dev.name
    );

    // Correctness first: every pricing path is bit-identical.
    let total_static: f64 = cells
        .iter()
        .map(|(g, prec)| RooflinePricer::new(dev.clone(), *prec).iteration_seconds(g))
        .sum();
    for (g, prec) in &cells {
        let base = RooflinePricer::new(dev.clone(), *prec);
        let want = base.iteration_seconds(g);
        let dynp: &dyn CostModel = &base;
        assert_eq!(want, dynp.iteration_seconds(g));
        assert_eq!(want, CalibratedPricer::identity(base.clone()).iteration_seconds(g));
        assert_eq!(want, Cached::new(base.clone()).iteration_seconds(g));
    }

    let pricers: Vec<RooflinePricer> = cells
        .iter()
        .map(|(_, prec)| RooflinePricer::new(dev.clone(), *prec))
        .collect();

    let mut b = Bench::new("fig_costmodel");
    let static_t = b
        .run("static dispatch (RooflinePricer)", || {
            let mut acc = 0.0;
            for ((g, _), p) in cells.iter().zip(&pricers) {
                acc += p.iteration_seconds(g);
            }
            black_box(acc);
        })
        .median;
    let dyn_t = b
        .run("dyn dispatch (&dyn CostModel)", || {
            let mut acc = 0.0;
            for ((g, _), p) in cells.iter().zip(&pricers) {
                let m: &dyn CostModel = p;
                acc += m.iteration_seconds(g);
            }
            black_box(acc);
        })
        .median;
    let calibrated: Vec<CalibratedPricer<RooflinePricer>> =
        pricers.iter().cloned().map(CalibratedPricer::identity).collect();
    let ident_t = b
        .run("identity CalibratedPricer decorator", || {
            let mut acc = 0.0;
            for ((g, _), p) in cells.iter().zip(&calibrated) {
                acc += p.iteration_seconds(g);
            }
            black_box(acc);
        })
        .median;
    let cold_t = b
        .run("Cached cold (fresh table per pass)", || {
            let table = Arc::new(CostCache::new());
            let mut acc = 0.0;
            for ((g, _), p) in cells.iter().zip(&pricers) {
                acc += Cached::with_table(p.clone(), Arc::clone(&table)).iteration_seconds(g);
            }
            black_box(acc);
        })
        .median;
    let warm_table = Arc::new(CostCache::new());
    let warm_pricers: Vec<Cached<RooflinePricer>> = pricers
        .iter()
        .map(|p| Cached::with_table(p.clone(), Arc::clone(&warm_table)))
        .collect();
    let warm_t = b
        .run("Cached warm (grid-lifetime table)", || {
            let mut acc = 0.0;
            for ((g, _), p) in cells.iter().zip(&warm_pricers) {
                acc += p.iteration_seconds(g);
            }
            black_box(acc);
        })
        .median;
    b.finish();

    let ratio = |num: std::time::Duration, den: std::time::Duration| {
        num.as_secs_f64() / den.as_secs_f64()
    };
    println!(
        "dyn/static {:.3}x, identity-decorator/static {:.3}x, cold-cache/static {:.3}x, \
         warm-cache speedup {:.2}x (dedup {:.1}%)",
        ratio(dyn_t, static_t),
        ratio(ident_t, static_t),
        ratio(cold_t, static_t),
        ratio(static_t, warm_t),
        warm_table.dedup_rate() * 100.0
    );

    let out = Json::obj(vec![
        ("bench", Json::str("fig_costmodel")),
        ("grid_graphs", Json::num(cells.len() as f64)),
        ("grid_ops", Json::num(ops as f64)),
        ("modeled_grid_seconds", Json::num(total_static)),
        ("static_median_us", Json::num(static_t.as_secs_f64() * 1e6)),
        ("dyn_median_us", Json::num(dyn_t.as_secs_f64() * 1e6)),
        ("identity_calibrated_median_us", Json::num(ident_t.as_secs_f64() * 1e6)),
        ("cached_cold_median_us", Json::num(cold_t.as_secs_f64() * 1e6)),
        ("cached_warm_median_us", Json::num(warm_t.as_secs_f64() * 1e6)),
        ("dyn_overhead", Json::num(ratio(dyn_t, static_t))),
        ("identity_decorator_overhead", Json::num(ratio(ident_t, static_t))),
        ("cached_cold_overhead", Json::num(ratio(cold_t, static_t))),
        ("cached_warm_speedup", Json::num(ratio(static_t, warm_t))),
        ("warm_dedup_rate", Json::num(warm_table.dedup_rate())),
    ]);
    let path = "BENCH_costmodel.json";
    std::fs::write(path, out.to_string()).expect("write bench artifact");
    println!("wrote {path}");
}
