//! Fig. 5 — hierarchical breakdown of the transformer layers (FP32 and
//! mixed precision): attention vs FC vs DR+Res+LN, linear-transform GEMMs
//! vs B-GEMMs vs softmax chain, FC GEMMs vs GeLU.
use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::perf::device::DeviceSpec;
use bertprof::profiler::{report, Timeline};
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let dev = DeviceSpec::mi100();
    let f32r = RunConfig::new(ModelConfig::bert_large(), Phase::Phase1, Precision::Fp32);
    let mpr = RunConfig::new(ModelConfig::bert_large(), Phase::Phase1, Precision::Mixed);
    let ts = vec![Timeline::modeled(&f32r, &dev), Timeline::modeled(&mpr, &dev)];
    println!("{}", report::category_table(
        "Fig. 5 — transformer-layer breakdown (fractions of iteration)", &ts));

    let mut b = Bench::new("fig05");
    b.run("category aggregation", || {
        black_box(ts[0].by_category());
    });
    b.run("both precisions end-to-end", || {
        for r in [&f32r, &mpr] {
            black_box(Timeline::modeled(r, &dev).category_fractions());
        }
    });
    b.finish();
}
