//! Fig. 8 — arithmetic intensity and normalized bandwidth demand of all
//! BERT op categories (LAMB stages, attention EW, GeLU, DR+Res+LN, GEMMs).
use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::perf::intensity;
use bertprof::profiler::report;
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let run = RunConfig::new(ModelConfig::bert_large(), Phase::Phase1, Precision::Fp32);
    let rows = intensity::op_intensities(&run);
    let a: Vec<(String, f64)> = rows.iter().map(|r| (r.label.clone(), r.ops_per_byte)).collect();
    let bw: Vec<(String, f64)> = rows.iter().map(|r| (r.label.clone(), r.bandwidth)).collect();
    println!("{}", report::series_table(
        "Fig. 8a — op arithmetic intensity", ("category", "ops/byte"), &a));
    println!("{}", report::series_table(
        "Fig. 8b — bandwidth demand (normalized to max EW)", ("category", "bw"), &bw));

    let mut b = Bench::new("fig08");
    b.run("op_intensities (full iteration)", || {
        black_box(intensity::op_intensities(&run));
    });
    b.finish();
}
