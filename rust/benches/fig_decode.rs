//! The decode bench: what does a generation step cost to price, and
//! what does the decode simulator cost to run?
//!
//! Three questions on the SSDecode grid (DESIGN.md):
//!
//! 1. **Step pricing** — a decode-graph build + roofline pass, cold
//!    (fresh pricer) vs warm (memoized `DecodeModel`), across the
//!    {batch x KV-depth} shape grid the sweep touches.
//! 2. **Scheduler cost** — one FIFO lock-step run vs one continuous
//!    -batching run over the same trace (the simulator bookkeeping,
//!    with all step prices already memoized).
//! 3. **Headline sanity** — the bench asserts the cache-0 pricing
//!    identity and token conservation before timing anything.
//!
//! Results land in `BENCH_decode.json` (wired into `make artifacts`).

use bertprof::config::{ModelConfig, Precision};
use bertprof::perf::device::DeviceSpec;
use bertprof::perf::{CostModel, RooflinePricer};
use bertprof::serve::{
    decode_graph, forward_graph, inference_run, BatchPolicy, ContinuousBatchPolicy, DecodeModel,
    DecodePolicy, DecodeSimulator, DecodeWorkload, ServeHead,
};
use bertprof::util::bench::{black_box, Bench};
use bertprof::util::Json;

fn main() {
    let dev = DeviceSpec::mi100();
    let prec = Precision::Mixed;
    let shapes: Vec<(u64, u64)> = [1u64, 8, 32]
        .iter()
        .flat_map(|&b| [32u64, 128, 512].iter().map(move |&kv| (b, kv)))
        .collect();
    println!(
        "## fig_decode — {} decode shapes on {}, plus one {}-request sim per scheduler",
        shapes.len(),
        dev.name,
        800
    );

    // Correctness first: the cache-0 identity and token conservation.
    let pricer = RooflinePricer::new(dev.clone(), prec);
    let run = inference_run(ModelConfig::bert_large(), 8, 1, prec);
    assert_eq!(
        pricer.iteration_seconds(&forward_graph(&run, ServeHead::Squad)),
        pricer.iteration_seconds(&decode_graph(&run, ServeHead::Squad, 0)),
        "decode at cache 0 must price as the seq-1 forward slice"
    );
    let trace = DecodeWorkload::poisson(18.0, 800, 42).generate();
    let want_tokens: u64 = trace.iter().map(|r| r.output_len).sum();
    let mut prefill =
        bertprof::serve::LatencyModel::new(ModelConfig::bert_large(), prec, dev.clone());
    let mut decode = DecodeModel::new(ModelConfig::bert_large(), prec, dev.clone());
    for policy in [
        DecodePolicy::Fifo(BatchPolicy::new(16, 0.010)),
        DecodePolicy::Continuous(ContinuousBatchPolicy::new(16)),
    ] {
        let out = DecodeSimulator::new(policy, 2.0).run("warm", &trace, &mut prefill, &mut decode);
        assert_eq!(out.tokens, want_tokens, "{}", policy.label());
    }

    let mut b = Bench::new("fig_decode");
    let cold_t = b
        .run("cold step pricing (graph build + roofline)", || {
            let mut acc = 0.0;
            for &(batch, kv) in &shapes {
                let r = inference_run(ModelConfig::bert_large(), batch, 1, prec);
                acc += pricer.iteration_seconds(&decode_graph(&r, ServeHead::Squad, kv));
            }
            black_box(acc);
        })
        .median;
    let warm_t = b
        .run("warm step pricing (DecodeModel memo)", || {
            let mut acc = 0.0;
            for &(batch, kv) in &shapes {
                acc += decode.step_seconds(batch, kv);
            }
            black_box(acc);
        })
        .median;
    let fifo_t = b
        .run("FIFO lock-step simulation (800 req)", || {
            let out = DecodeSimulator::new(DecodePolicy::Fifo(BatchPolicy::new(16, 0.010)), 2.0)
                .run("fifo", &trace, &mut prefill, &mut decode);
            black_box(out.report.goodput);
        })
        .median;
    let cont_t = b
        .run("continuous-batching simulation (800 req)", || {
            let out = DecodeSimulator::new(
                DecodePolicy::Continuous(ContinuousBatchPolicy::new(16)),
                2.0,
            )
            .run("cont", &trace, &mut prefill, &mut decode);
            black_box(out.report.goodput);
        })
        .median;
    b.finish();

    let us = |d: std::time::Duration| d.as_secs_f64() * 1e6;
    println!(
        "warm-step speedup {:.1}x over cold; continuous/fifo sim cost {:.2}x",
        us(cold_t) / us(warm_t).max(1e-9),
        us(cont_t) / us(fifo_t).max(1e-9)
    );

    let out = Json::obj(vec![
        ("bench", Json::str("fig_decode")),
        ("shapes", Json::num(shapes.len() as f64)),
        ("sim_requests", Json::num(800.0)),
        ("cold_step_median_us", Json::num(us(cold_t))),
        ("warm_step_median_us", Json::num(us(warm_t))),
        ("fifo_sim_median_us", Json::num(us(fifo_t))),
        ("continuous_sim_median_us", Json::num(us(cont_t))),
        ("warm_step_speedup", Json::num(us(cold_t) / us(warm_t).max(1e-9))),
        ("decode_shapes_cached", Json::num(decode.cached_points() as f64)),
    ]);
    let path = "BENCH_decode.json";
    std::fs::write(path, out.to_string()).expect("write bench artifact");
    println!("wrote {path}");
}
