//! Fig. 7 — arithmetic intensity (ops/byte) of every BERT training GEMM;
//! [MB] marks GEMMs the device model classifies memory-bound.
use bertprof::config::{ModelConfig, Phase, Precision, RunConfig};
use bertprof::perf::intensity;
use bertprof::profiler::report;
use bertprof::util::bench::{black_box, Bench};

fn main() {
    let run = RunConfig::new(ModelConfig::bert_large(), Phase::Phase1, Precision::Fp32);
    let rows: Vec<(String, f64)> = intensity::gemm_intensities(&run)
        .into_iter()
        .map(|r| (format!("{}{}", if r.memory_bound { "[MB] " } else { "     " }, r.label),
                  r.ops_per_byte))
        .collect();
    println!("{}", report::series_table(
        "Fig. 7 — GEMM arithmetic intensity (Ph1 B=32 FP32)",
        ("GEMM (M,N,K[,b])", "ops/byte"), &rows));

    let mut b = Bench::new("fig07");
    b.run("gemm_intensities", || {
        black_box(intensity::gemm_intensities(&run));
    });
    b.finish();
}
